//! Wire protocol: line-delimited JSON over TCP.
//!
//! The complete command reference — request/response examples, error
//! shapes, backpressure and retention semantics — lives in
//! **`PROTOCOL.md`** at the repository root (also rendered into rustdoc
//! as [`crate::coordinator::protocol_doc`]). Summary of the requests
//! (one JSON object per line):
//! ```json
//! {"cmd":"solve","profile":"mnist-like","n":1024,"d":128,"nu":1.0,
//!  "solver":"adaptive-srht","eps":1e-8,"seed":7,"threads":8}
//! {"cmd":"status","job":3}
//! {"cmd":"wait","job":3,"timeout_s":60}
//! {"cmd":"result","job":3,"include_x":true}
//! {"cmd":"register","profile":"exp","n":1024,"d":128,"seed":7,
//!  "sketch":"gaussian","name":"exp-1k"}
//! {"cmd":"query","model":1,"nu":0.5,"eps":1e-8,"include_x":true}
//! {"cmd":"query","model":1,"nus":[10,1,0.1]}
//! {"cmd":"query","model":1,"nu":0.5,"bs":[[...],[...]]}
//! {"cmd":"predict","model":1,"nu":0.5,"rows":[[0.1,0.2],[0.3,0.4]]}
//! {"cmd":"append","model":1,"rows":2,"cols":2,
//!  "triplets":[[0,0,1.0],[1,1,2.0]],"b":[0.5,0.25],"refresh":"eager"}
//! {"cmd":"evict","model":1}
//! {"cmd":"evict","model":1,"purge":true}
//! {"cmd":"snapshot"}
//! {"cmd":"snapshot","model":1}
//! {"cmd":"models"}
//! {"cmd":"metrics"}
//! {"cmd":"health"}
//! {"cmd":"solvers"}
//! {"cmd":"ping"}
//! {"cmd":"shutdown"}
//! ```
//! Responses: `{"ok":true, ...}` or `{"ok":false,"error":"..."}`.
//!
//! **Pipelining:** any request may add `"id":N` (non-negative integer).
//! Tagged requests are answered asynchronously with the id echoed as the
//! first response field — `{"id":N,"ok":true,...}` — and may come back
//! in completion order, so one connection can keep many requests in
//! flight (see `PROTOCOL.md` §Concurrency and [`decode_tagged`]).
//!
//! Robustness contract (see `PROTOCOL.md` §Errors): `nu`/`eps` are
//! validated *at decode* — non-positive or non-finite values answer
//! `{"ok":false,"error":"invalid nu: ..."}` before any solver state is
//! touched. `query`/`predict`/`append` accept an optional `"deadline_s"`
//! (positive, finite seconds): a request that exceeds its wall deadline
//! mid-solve rolls the session back and answers a
//! `"deadline exceeded: ..."` error. `health` reports liveness plus
//! scheduler/registry load without touching any model.
//!
//! The `"solver"` field of a solve request is a [`SolverSpec`] string
//! (`"cg"`, `"adaptive-srht"`, `"ihs-sparse@m=256"`, ...); `"solvers"`
//! returns the full registry for client-side discovery. An optional
//! `"threads"` field pins the parallel dense kernels for the whole job
//! (equivalent to the `@threads=k` spec param, but also covering the
//! oracle solve).
//!
//! Sparse inputs: `"profile":"sparse"` plus an optional `"density"` field
//! generates a density-controlled CSR workload server-side, and small
//! real problems ship inline as CSR triplets —
//! `{"cmd":"solve","rows":3,"cols":2,"triplets":[[0,0,1.5],...],"b":[...]}`
//! — which bypass the synthetic profile entirely. `register` accepts the
//! same workload fields as `solve` (synthetic profiles and inline
//! triplets alike).

use super::job::{JobSpec, Workload};
use crate::linalg::sparse::CsrMatrix;
use crate::linalg::Operand;
use crate::sketch::SketchKind;
use crate::solvers::api::SolverSpec;
use crate::util::json::{self, Json};

/// A decoded client request.
#[derive(Clone, Debug)]
pub enum Request {
    /// Submit an asynchronous solve job (returns a job id).
    Solve(JobSpec),
    /// Poll a job's lifecycle state.
    Status {
        /// Job id from a `solve` response.
        job: u64,
    },
    /// Block until the job is terminal or the timeout elapses.
    Wait {
        /// Job id from a `solve` response.
        job: u64,
        /// Maximum seconds to block.
        timeout_s: f64,
    },
    /// Fetch a terminal job's result.
    Result {
        /// Job id from a `solve` response.
        job: u64,
        /// Whether to include the solution vector.
        include_x: bool,
    },
    /// Register a model: same workload fields as `solve`, plus the sketch
    /// family to grow and an optional display name.
    Register {
        /// The data to register (synthetic profile or inline triplets).
        workload: Workload,
        /// Sketch family the model's session grows (`"sketch"` field).
        kind: SketchKind,
        /// Seed for the session's sketch stream.
        seed: u64,
        /// Optional display name (defaults to a workload description).
        name: Option<String>,
    },
    /// Query a registered model: a solve at `nu` (or a batched path over
    /// `nus`), optionally against one alternate right-hand side (`b`) or
    /// a whole batch of them (`bs`, the block multi-RHS path).
    Query {
        /// Model id from a `register` response.
        model: u64,
        /// Regularization level (ignored when `nus` is non-empty).
        nu: f64,
        /// Non-empty: batched warm-started path over these strictly
        /// decreasing values.
        nus: Vec<f64>,
        /// Gradient-norm tolerance (sessions are oracle-free).
        eps: f64,
        /// Whether to include solution vectors in the response.
        include_x: bool,
        /// Alternate right-hand side (length `n`); exclusive with `nus`
        /// and `bs`.
        b: Option<Vec<f64>>,
        /// Batch of alternate right-hand sides (each length `n`), solved
        /// jointly through one BLAS-3 block iteration
        /// ([`crate::solvers::block`]); exclusive with `b` and `nus`.
        bs: Option<Vec<Vec<f64>>>,
        /// Optional per-request wall deadline in seconds; the solve rolls
        /// back and errors if it runs past it.
        deadline_s: Option<f64>,
    },
    /// Predict on new rows with a registered model's solution at `nu`.
    Predict {
        /// Model id from a `register` response.
        model: u64,
        /// Regularization level whose solution to use.
        nu: f64,
        /// Rows to score, each of length `d`.
        rows: Vec<Vec<f64>>,
        /// Tolerance for the underlying solve if not already cached.
        eps: f64,
        /// Optional per-request wall deadline in seconds.
        deadline_s: Option<f64>,
    },
    /// Stream new observation rows into a registered model. The payload is
    /// the inline-triplet shape (`"rows"`/`"cols"`/`"triplets"`/`"b"`)
    /// describing the *delta* block: `rows` = number of appended rows,
    /// `cols` must equal the model's `d`, `b` carries the new
    /// observations. Retained rows are never re-sketched — the session
    /// updates its sketch and factorization incrementally
    /// ([`crate::solvers::session::ModelSession::append`]).
    Append {
        /// Model id from a `register` response.
        model: u64,
        /// The appended rows (decoded CSR delta block, `rows x d`).
        a: Operand,
        /// The appended observations (length `rows`).
        b: Vec<f64>,
        /// Staleness policy: `true` (`"refresh":"eager"`, the default)
        /// refreshes sketch + factorization inside the append; `false`
        /// (`"refresh":"lazy"`) defers the downstream update to the next
        /// query.
        eager: bool,
        /// Optional per-request wall deadline in seconds; on expiry the
        /// append rolls back completely (no rows retained).
        deadline_s: Option<f64>,
    },
    /// Drop a registered model, freeing its cached state. With a durable
    /// state dir this is a *spill* (the model reloads on its next touch)
    /// unless `purge` also deletes the on-disk state.
    Evict {
        /// Model id from a `register` response.
        model: u64,
        /// Whether to delete the model's persisted snapshot + WAL too
        /// (`"purge":true`); ignored without a state dir.
        purge: bool,
    },
    /// Force a durable snapshot of one model (or all of them), flushing
    /// pending appends and resetting the WAL. Errors without a state dir.
    Snapshot {
        /// Restrict to one model (`"model"`); absent = every live model.
        model: Option<u64>,
    },
    /// List the registered models.
    Models,
    /// Process metrics snapshot (scheduler + registry).
    Metrics,
    /// Liveness/load probe: backlog, in-flight connections, registered
    /// models, drain state — never touches a model session.
    Health,
    /// List every available solver spec.
    Solvers,
    /// Liveness check.
    Ping,
    /// Stop the server after in-flight work completes.
    Shutdown,
}

/// Decode one request line, discarding any pipelining tag (see
/// [`decode_tagged`]). A malformed `"id"` field is still an error — the
/// tag is part of the wire contract whether or not the caller uses it.
pub fn decode(line: &str) -> Result<Request, String> {
    decode_tagged(line).map(|(_, req)| req)
}

/// Decode one request line together with its optional `"id"` pipelining
/// tag.
///
/// Any request may carry `"id"` (a non-negative integer `< 2^53`, the
/// exact-in-f64 range — same strictness as `"job"`/`"model"` ids): the
/// server then answers **asynchronously**, echoing the id as the first
/// field of the response line, and tagged responses on one connection
/// may arrive in any order (completion order, not submission order).
/// Untagged requests keep the classic synchronous one-in/one-out
/// contract. `null` means absent, like every optional field; any other
/// non-integer value is a decode error rather than a silently dropped
/// tag — a client that thinks it tagged a request must never get an
/// untagged (uncorrelatable) response back.
pub fn decode_tagged(line: &str) -> Result<(Option<u64>, Request), String> {
    let v = json::parse(line.trim()).map_err(|e| e.to_string())?;
    let id = decode_request_id(&v)?;
    Ok((id, decode_value(v)?))
}

/// Strict optional request id: absent / `null` → `None`; anything
/// non-integral, negative, or above the f64-exact range is an error.
fn decode_request_id(v: &Json) -> Result<Option<u64>, String> {
    match v.get("id") {
        None | Some(Json::Null) => Ok(None),
        Some(j) => {
            let x = j.as_f64().ok_or("request id must be a number")?;
            if !(x.is_finite() && x >= 0.0 && x.fract() == 0.0 && x < 9_007_199_254_740_992.0)
            {
                return Err(format!("request id must be a non-negative integer, got {x}"));
            }
            Ok(Some(x as u64))
        }
    }
}

fn decode_value(v: Json) -> Result<Request, String> {
    let cmd = v.get("cmd").and_then(Json::as_str).ok_or("missing cmd")?;
    match cmd {
        "solve" => {
            let nu = decode_nu(&v)?;
            let eps = decode_eps(&v)?;
            let seed = v.get("seed").and_then(Json::as_f64).unwrap_or(0.0) as u64;
            let solver_name = v.get("solver").and_then(Json::as_str).unwrap_or("adaptive");
            let solver: SolverSpec = solver_name.parse()?;
            let workload = decode_workload(&v, seed)?;
            // Optional "nus": [..] turns the job into a warm-started
            // regularization path (Figure-1 workload as a service).
            let path_nus = decode_nus(&v)?;
            let threads = match v.get("threads").and_then(Json::as_usize) {
                Some(0) => return Err("threads must be >= 1".into()),
                t => t,
            };
            Ok(Request::Solve(JobSpec { workload, nu, solver, eps, seed, path_nus, threads }))
        }
        "register" => {
            let seed = v.get("seed").and_then(Json::as_f64).unwrap_or(0.0) as u64;
            let kind: SketchKind = match v.get("sketch").and_then(Json::as_str) {
                Some(s) => s.parse()?,
                None => SketchKind::Gaussian,
            };
            let workload = decode_workload(&v, seed)?;
            let name = v.get("name").and_then(Json::as_str).map(str::to_string);
            Ok(Request::Register { workload, kind, seed, name })
        }
        "query" => {
            let model = require_id(&v, "model")?;
            let nu = decode_nu(&v)?;
            let nus = decode_nus(&v)?;
            let eps = decode_eps(&v)?;
            let deadline_s = decode_deadline(&v)?;
            let include_x = v.get("include_x").and_then(Json::as_bool).unwrap_or(false);
            // A present-but-non-array "b" must be an error, not a silent
            // fall-through to a state-mutating solve of the registered b.
            // `null` unambiguously means absent (serializers commonly
            // emit it for unset optionals) and stays accepted.
            let b = match v.get("b") {
                None | Some(Json::Null) => None,
                Some(raw) => {
                    let arr = raw.as_arr().ok_or("\"b\" must be an array of numbers")?;
                    Some(decode_f64_vec(arr, "b")?)
                }
            };
            // Batched right-hand sides: an array of length-n arrays.
            // Strict like "nus": a non-array value, an empty batch or a
            // malformed entry is an error, never a silently smaller
            // batch (or, worse, a silently *ignored* one).
            let bs = match v.get("bs") {
                None | Some(Json::Null) => None,
                Some(raw) => {
                    let arr = raw.as_arr().ok_or("\"bs\" must be an array of arrays")?;
                    if arr.is_empty() {
                        return Err("\"bs\" must contain at least one right-hand side".into());
                    }
                    let mut out = Vec::with_capacity(arr.len());
                    for (i, row) in arr.iter().enumerate() {
                        let row = row
                            .as_arr()
                            .ok_or_else(|| format!("\"bs\" entry {i} must be an array"))?;
                        out.push(decode_f64_vec(row, "bs")?);
                    }
                    Some(out)
                }
            };
            if b.is_some() && !nus.is_empty() {
                return Err("\"b\" and \"nus\" cannot be combined in one query".into());
            }
            if bs.is_some() && (b.is_some() || !nus.is_empty()) {
                return Err("\"bs\" cannot be combined with \"b\" or \"nus\" in one query".into());
            }
            Ok(Request::Query { model, nu, nus, eps, include_x, b, bs, deadline_s })
        }
        "predict" => {
            let model = require_id(&v, "model")?;
            let nu = decode_nu(&v)?;
            let eps = decode_eps(&v)?;
            let deadline_s = decode_deadline(&v)?;
            let rows_json = v.get("rows").and_then(Json::as_arr).ok_or("predict needs \"rows\"")?;
            let mut rows = Vec::with_capacity(rows_json.len());
            for (i, r) in rows_json.iter().enumerate() {
                let r = r.as_arr().ok_or_else(|| format!("predict row {i} must be an array"))?;
                rows.push(decode_f64_vec(r, "rows")?);
            }
            if rows.is_empty() {
                return Err("predict needs at least one row".into());
            }
            Ok(Request::Predict { model, nu, rows, eps, deadline_s })
        }
        "append" => {
            let model = require_id(&v, "model")?;
            let deadline_s = decode_deadline(&v)?;
            // The delta ships in the same inline-triplet shape register
            // uses; synthetic profiles make no sense for an append.
            let trips = v
                .get("triplets")
                .and_then(Json::as_arr)
                .ok_or("append needs inline \"triplets\" (plus \"rows\"/\"cols\"/\"b\")")?;
            let (a, b) = match decode_triplet_workload(&v, trips)? {
                Workload::Inline { a, b } => (a, b),
                _ => unreachable!("triplet decode always yields an inline workload"),
            };
            // Strict like every other optional: a present-but-unknown
            // "refresh" is an error, never a silent eager refresh.
            let eager = match v.get("refresh") {
                None | Some(Json::Null) => true,
                Some(raw) => match raw.as_str() {
                    Some("eager") => true,
                    Some("lazy") => false,
                    _ => return Err("\"refresh\" must be \"eager\" or \"lazy\"".into()),
                },
            };
            Ok(Request::Append { model, a, b, eager, deadline_s })
        }
        "evict" => {
            // Strict like "refresh": a present-but-non-bool purge is an
            // error, never a silent spill (or worse, a silent purge).
            let purge = match v.get("purge") {
                None | Some(Json::Null) => false,
                Some(raw) => raw.as_bool().ok_or("\"purge\" must be true or false")?,
            };
            Ok(Request::Evict { model: require_id(&v, "model")?, purge })
        }
        "snapshot" => {
            let model = match v.get("model") {
                None | Some(Json::Null) => None,
                Some(_) => Some(require_id(&v, "model")?),
            };
            Ok(Request::Snapshot { model })
        }
        "models" => Ok(Request::Models),
        "status" => Ok(Request::Status { job: require_job(&v)? }),
        "wait" => Ok(Request::Wait {
            job: require_job(&v)?,
            timeout_s: v.get("timeout_s").and_then(Json::as_f64).unwrap_or(120.0),
        }),
        "result" => Ok(Request::Result {
            job: require_job(&v)?,
            include_x: v.get("include_x").and_then(Json::as_bool).unwrap_or(false),
        }),
        "metrics" => Ok(Request::Metrics),
        "health" => Ok(Request::Health),
        "solvers" => Ok(Request::Solvers),
        "ping" => Ok(Request::Ping),
        "shutdown" => Ok(Request::Shutdown),
        other => Err(format!("unknown cmd: {other}")),
    }
}

/// Decode the workload fields shared by `solve` and `register`: either a
/// synthetic `"profile"` (+ optional `"density"` for the sparse family)
/// or an inline CSR triplet payload.
fn decode_workload(v: &Json, seed: u64) -> Result<Workload, String> {
    let mut profile = v.get("profile").and_then(Json::as_str).unwrap_or("exp").to_string();
    let n = v.get("n").and_then(Json::as_usize).unwrap_or(1024);
    let d = v.get("d").and_then(Json::as_usize).unwrap_or(128);
    // Optional "density": only meaningful for the sparse profile.
    if let Some(dens) = v.get("density").and_then(Json::as_f64) {
        if profile != "sparse" {
            return Err(format!("\"density\" requires \"profile\":\"sparse\" (got {profile:?})"));
        }
        if !(dens > 0.0 && dens <= 1.0) {
            return Err(format!("density must be in (0, 1], got {dens}"));
        }
        profile = format!("sparse:{dens}");
    }
    // Optional inline CSR payload: triplets + rows/cols + b.
    if let Some(trips) = v.get("triplets").and_then(Json::as_arr) {
        decode_triplet_workload(v, trips)
    } else {
        Ok(Workload::Synthetic { profile, n, d, seed })
    }
}

/// Optional `"nu"` (default 1.0). Rejected at decode when non-positive or
/// non-finite — the solver stack would refuse it anyway, but catching it
/// here guarantees no session state is ever touched by an invalid level.
fn decode_nu(v: &Json) -> Result<f64, String> {
    match v.get("nu") {
        None | Some(Json::Null) => Ok(1.0),
        Some(raw) => {
            let nu = raw.as_f64().ok_or("invalid nu: must be a number")?;
            if !(nu.is_finite() && nu > 0.0) {
                return Err(format!("invalid nu: must be positive and finite, got {nu}"));
            }
            Ok(nu)
        }
    }
}

/// Optional `"eps"` (default 1e-8), same strictness as [`decode_nu`].
fn decode_eps(v: &Json) -> Result<f64, String> {
    match v.get("eps") {
        None | Some(Json::Null) => Ok(1e-8),
        Some(raw) => {
            let eps = raw.as_f64().ok_or("invalid eps: must be a number")?;
            if !(eps.is_finite() && eps > 0.0) {
                return Err(format!("invalid eps: must be positive and finite, got {eps}"));
            }
            Ok(eps)
        }
    }
}

/// Optional `"deadline_s"`: positive, finite seconds of wall budget for
/// this request; `None`/`null` means the server-wide default (if any).
fn decode_deadline(v: &Json) -> Result<Option<f64>, String> {
    match v.get("deadline_s") {
        None | Some(Json::Null) => Ok(None),
        Some(raw) => {
            let s = raw.as_f64().ok_or("invalid deadline_s: must be a number of seconds")?;
            if !(s.is_finite() && s > 0.0) {
                return Err(format!("invalid deadline_s: must be positive and finite, got {s}"));
            }
            Ok(Some(s))
        }
    }
}

/// Optional `"nus"` array (empty when absent or `null`). Strict: a
/// non-array value or a non-numeric entry is an error, not a silently
/// shorter (or empty) path — an empty result must mean the client did
/// not ask for a path.
fn decode_nus(v: &Json) -> Result<Vec<f64>, String> {
    match v.get("nus") {
        None | Some(Json::Null) => Ok(Vec::new()),
        Some(raw) => {
            let arr = raw.as_arr().ok_or("\"nus\" must be an array of numbers")?;
            let nus = decode_f64_vec(arr, "nus")?;
            if let Some(bad) = nus.iter().find(|&&x| x <= 0.0) {
                return Err(format!("invalid nu: path entries must be positive, got {bad}"));
            }
            Ok(nus)
        }
    }
}

/// Decode an array of finite numbers, naming the field in errors.
fn decode_f64_vec(arr: &[Json], field: &str) -> Result<Vec<f64>, String> {
    let mut out = Vec::with_capacity(arr.len());
    for x in arr {
        let v = x.as_f64().ok_or_else(|| format!("non-numeric entry in {field:?}"))?;
        if !v.is_finite() {
            return Err(format!("non-finite entry in {field:?}"));
        }
        out.push(v);
    }
    Ok(out)
}

/// Decode an inline CSR workload: `"rows"`, `"cols"`, `"triplets"` (array
/// of `[row, col, value]`) and `"b"` (length `rows`).
fn decode_triplet_workload(v: &Json, trips: &[Json]) -> Result<Workload, String> {
    let rows = v.get("rows").and_then(Json::as_usize).ok_or("triplets need \"rows\"")?;
    let cols = v.get("cols").and_then(Json::as_usize).ok_or("triplets need \"cols\"")?;
    if rows == 0 || cols == 0 {
        return Err("triplet workload needs rows > 0 and cols > 0".into());
    }
    let b_json = v.get("b").and_then(Json::as_arr).ok_or("triplets need \"b\"")?;
    let mut b = Vec::with_capacity(b_json.len());
    for x in b_json {
        let bv = x.as_f64().ok_or("non-numeric entry in \"b\"")?;
        if !bv.is_finite() {
            return Err("non-finite entry in \"b\"".into());
        }
        b.push(bv);
    }
    if b.len() != rows {
        return Err(format!("\"b\" has {} entries, expected rows = {rows}", b.len()));
    }
    let mut triplets = Vec::with_capacity(trips.len());
    for (k, t) in trips.iter().enumerate() {
        let t = t.as_arr().ok_or_else(|| format!("triplet {k} must be [row, col, value]"))?;
        if t.len() != 3 {
            return Err(format!("triplet {k} must have exactly 3 entries"));
        }
        let r = t[0].as_usize().ok_or_else(|| format!("bad row in triplet {k}"))?;
        let c = t[1].as_usize().ok_or_else(|| format!("bad col in triplet {k}"))?;
        let val = t[2].as_f64().ok_or_else(|| format!("bad value in triplet {k}"))?;
        if r >= rows || c >= cols {
            return Err(format!("triplet {k} ({r},{c}) out of bounds for {rows} x {cols}"));
        }
        if !val.is_finite() {
            return Err(format!("triplet {k} has non-finite value"));
        }
        triplets.push((r, c, val));
    }
    let a = Operand::Sparse(CsrMatrix::from_triplets(rows, cols, &triplets));
    Ok(Workload::Inline { a, b })
}

fn require_job(v: &Json) -> Result<u64, String> {
    require_id(v, "job")
}

/// Required numeric id field (`"job"` / `"model"`). Strict: fractional,
/// negative, or non-integral values are rejected instead of being cast —
/// a truncated/saturated id would silently address a *different* job or
/// model than the client named.
fn require_id(v: &Json, field: &str) -> Result<u64, String> {
    let x = v
        .get(field)
        .and_then(Json::as_f64)
        .ok_or_else(|| format!("missing {field} id"))?;
    if !(x.is_finite() && x >= 0.0 && x.fract() == 0.0 && x < 9_007_199_254_740_992.0) {
        return Err(format!("{field} id must be a non-negative integer, got {x}"));
    }
    Ok(x as u64)
}

/// Encode a success response.
pub fn ok(mut fields: Vec<(&str, Json)>) -> String {
    fields.insert(0, ("ok", Json::Bool(true)));
    Json::obj(fields).to_string()
}

/// Encode an error response.
pub fn err(message: &str) -> String {
    Json::obj(vec![("ok", Json::Bool(false)), ("error", Json::from(message))]).to_string()
}

/// Encode an error response with extra machine-readable fields (e.g. the
/// overload shed's `retry_after_s` hint).
pub fn err_with(message: &str, mut fields: Vec<(&str, Json)>) -> String {
    let mut all = vec![("ok", Json::Bool(false)), ("error", Json::from(message))];
    all.append(&mut fields);
    Json::obj(all).to_string()
}

/// Splice a request's `"id"` tag into an already-encoded response line,
/// as its first field — the pipelining correlation contract. Every
/// encoder above produces a non-empty JSON object, so the splice is a
/// plain prefix rewrite; keeping it at the encoding layer means the
/// server tags `ok` and `err` responses identically.
pub fn tag_response(id: u64, response: &str) -> String {
    debug_assert!(
        response.starts_with('{') && response.len() > 2,
        "responses are non-empty JSON objects"
    );
    let mut out = String::with_capacity(response.len() + 24);
    out.push_str("{\"id\":");
    out.push_str(&id.to_string());
    out.push(',');
    out.push_str(&response[1..]);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decode_tagged_reads_the_id_and_the_request() {
        let (id, req) = decode_tagged(r#"{"cmd":"ping","id":7}"#).unwrap();
        assert_eq!(id, Some(7));
        assert!(matches!(req, Request::Ping));
    }

    #[test]
    fn untagged_requests_decode_with_no_id() {
        let (id, req) = decode_tagged(r#"{"cmd":"ping"}"#).unwrap();
        assert_eq!(id, None);
        assert!(matches!(req, Request::Ping));
        // `null` means absent, like every optional field.
        let (id, _) = decode_tagged(r#"{"cmd":"ping","id":null}"#).unwrap();
        assert_eq!(id, None);
    }

    #[test]
    fn request_id_zero_is_a_valid_tag() {
        let (id, _) = decode_tagged(r#"{"cmd":"ping","id":0}"#).unwrap();
        assert_eq!(id, Some(0));
    }

    #[test]
    fn malformed_request_ids_are_decode_errors() {
        // A client that thinks it tagged a request must never silently
        // get an uncorrelatable untagged response: reject, don't drop.
        for bad in [
            r#"{"cmd":"ping","id":1.5}"#,
            r#"{"cmd":"ping","id":-1}"#,
            r#"{"cmd":"ping","id":"7"}"#,
            r#"{"cmd":"ping","id":true}"#,
            r#"{"cmd":"ping","id":9007199254740992}"#,
        ] {
            let e = decode_tagged(bad).unwrap_err();
            assert!(e.contains("request id"), "{bad}: {e}");
            // The untagged decoder applies the same strictness.
            assert!(decode(bad).is_err(), "{bad} must fail decode() too");
        }
    }

    #[test]
    fn tag_response_splices_the_id_first() {
        assert_eq!(tag_response(3, r#"{"ok":true}"#), r#"{"id":3,"ok":true}"#);
        let tagged = tag_response(12, &err("boom"));
        assert!(tagged.starts_with(r#"{"id":12,"ok":false"#), "{tagged}");
        let parsed = json::parse(&tagged).unwrap();
        assert_eq!(parsed.get("id").unwrap().as_usize(), Some(12));
        assert_eq!(parsed.get("error").unwrap().as_str(), Some("boom"));
    }

    #[test]
    fn decode_solve_with_defaults() {
        let r = decode(r#"{"cmd":"solve"}"#).unwrap();
        match r {
            Request::Solve(spec) => {
                assert_eq!(spec.nu, 1.0);
                assert!(matches!(spec.workload, Workload::Synthetic { ref profile, .. } if profile == "exp"));
            }
            _ => panic!("wrong variant"),
        }
    }

    #[test]
    fn decode_full_solve() {
        let line = r#"{"cmd":"solve","profile":"cifar-like","n":2048,"d":256,"nu":0.1,
                       "solver":"adaptive-srht","eps":1e-10,"seed":42}"#;
        match decode(&line.replace('\n', " ")).unwrap() {
            Request::Solve(spec) => {
                assert_eq!(spec.eps, 1e-10);
                assert_eq!(spec.seed, 42);
                assert!(matches!(spec.solver, SolverSpec::Adaptive { .. }));
            }
            _ => panic!("wrong variant"),
        }
    }

    #[test]
    fn decode_spec_with_params() {
        let r = decode(r#"{"cmd":"solve","solver":"ihs-sparse@m=256"}"#).unwrap();
        match r {
            Request::Solve(spec) => assert_eq!(spec.solver.to_string(), "ihs-sparse@m=256"),
            _ => panic!("wrong variant"),
        }
    }

    #[test]
    fn decode_threads_field() {
        match decode(r#"{"cmd":"solve","threads":8}"#).unwrap() {
            Request::Solve(spec) => assert_eq!(spec.threads, Some(8)),
            _ => panic!("wrong variant"),
        }
        match decode(r#"{"cmd":"solve"}"#).unwrap() {
            Request::Solve(spec) => assert_eq!(spec.threads, None),
            _ => panic!("wrong variant"),
        }
        assert!(decode(r#"{"cmd":"solve","threads":0}"#).is_err());
        // The spec-level param also survives the wire.
        match decode(r#"{"cmd":"solve","solver":"adaptive-srht@threads=4"}"#).unwrap() {
            Request::Solve(spec) => {
                assert_eq!(spec.solver.to_string(), "adaptive-srht@threads=4")
            }
            _ => panic!("wrong variant"),
        }
    }

    #[test]
    fn decode_solvers_command() {
        assert!(matches!(decode(r#"{"cmd":"solvers"}"#).unwrap(), Request::Solvers));
    }

    #[test]
    fn decode_sparse_profile_and_density() {
        match decode(r#"{"cmd":"solve","profile":"sparse","density":0.05}"#).unwrap() {
            Request::Solve(spec) => match spec.workload {
                Workload::Synthetic { profile, .. } => assert_eq!(profile, "sparse:0.05"),
                other => panic!("wrong workload {other:?}"),
            },
            _ => panic!("wrong variant"),
        }
        // density without the sparse profile is rejected, as are bad values.
        assert!(decode(r#"{"cmd":"solve","density":0.05}"#).is_err());
        assert!(decode(r#"{"cmd":"solve","profile":"exp","density":0.05}"#).is_err());
        assert!(decode(r#"{"cmd":"solve","profile":"sparse","density":0}"#).is_err());
        assert!(decode(r#"{"cmd":"solve","profile":"sparse","density":1.5}"#).is_err());
    }

    #[test]
    fn decode_inline_triplets() {
        let line = r#"{"cmd":"solve","rows":3,"cols":2,
                       "triplets":[[0,0,1.5],[1,1,-2.0],[2,0,0.5]],
                       "b":[1.0,2.0,3.0],"solver":"cg"}"#;
        match decode(&line.replace('\n', " ")).unwrap() {
            Request::Solve(spec) => match spec.workload {
                Workload::Inline { a, b } => {
                    assert!(a.is_sparse());
                    assert_eq!((a.rows(), a.cols(), a.nnz()), (3, 2, 3));
                    assert_eq!(b, vec![1.0, 2.0, 3.0]);
                }
                other => panic!("wrong workload {other:?}"),
            },
            _ => panic!("wrong variant"),
        }
        // Malformed payloads are rejected with specific errors.
        assert!(decode(r#"{"cmd":"solve","triplets":[[0,0,1.0]],"b":[1.0]}"#).is_err(), "no rows");
        assert!(
            decode(r#"{"cmd":"solve","rows":2,"cols":2,"triplets":[[5,0,1.0]],"b":[1.0,1.0]}"#)
                .is_err(),
            "out of bounds"
        );
        assert!(
            decode(r#"{"cmd":"solve","rows":2,"cols":2,"triplets":[[0,0,1.0]],"b":[1.0]}"#)
                .is_err(),
            "b length"
        );
        assert!(
            decode(r#"{"cmd":"solve","rows":2,"cols":2,"triplets":[[0,0]],"b":[1.0,1.0]}"#)
                .is_err(),
            "triplet arity"
        );
    }

    #[test]
    fn decode_register() {
        let r = decode(
            r#"{"cmd":"register","profile":"exp","n":256,"d":32,"seed":9,
                "sketch":"srht","name":"demo"}"#
                .replace('\n', " ")
                .as_str(),
        )
        .unwrap();
        match r {
            Request::Register { workload, kind, seed, name } => {
                assert!(matches!(workload, Workload::Synthetic { ref profile, n: 256, d: 32, .. }
                    if profile == "exp"));
                assert_eq!(kind, SketchKind::Srht);
                assert_eq!(seed, 9);
                assert_eq!(name.as_deref(), Some("demo"));
            }
            _ => panic!("wrong variant"),
        }
        // Defaults: gaussian sketch, no name. Inline triplets also accepted.
        match decode(r#"{"cmd":"register","rows":2,"cols":1,"triplets":[[0,0,1.0],[1,0,2.0]],"b":[1.0,2.0]}"#).unwrap() {
            Request::Register { workload, kind, name, .. } => {
                assert!(matches!(workload, Workload::Inline { .. }));
                assert_eq!(kind, SketchKind::Gaussian);
                assert!(name.is_none());
            }
            _ => panic!("wrong variant"),
        }
        assert!(decode(r#"{"cmd":"register","sketch":"fourier"}"#).is_err());
    }

    #[test]
    fn decode_query_and_predict() {
        match decode(r#"{"cmd":"query","model":3,"nu":0.5,"eps":1e-6,"include_x":true}"#).unwrap()
        {
            Request::Query { model, nu, nus, eps, include_x, b, bs, deadline_s } => {
                assert_eq!(model, 3);
                assert_eq!(nu, 0.5);
                assert!(nus.is_empty());
                assert_eq!(eps, 1e-6);
                assert!(include_x);
                assert!(b.is_none());
                assert!(bs.is_none());
                assert!(deadline_s.is_none());
            }
            _ => panic!("wrong variant"),
        }
        match decode(r#"{"cmd":"query","model":1,"nus":[10,1,0.1]}"#).unwrap() {
            Request::Query { nus, .. } => assert_eq!(nus, vec![10.0, 1.0, 0.1]),
            _ => panic!("wrong variant"),
        }
        match decode(r#"{"cmd":"query","model":1,"b":[1.0,2.0]}"#).unwrap() {
            Request::Query { b, .. } => assert_eq!(b, Some(vec![1.0, 2.0])),
            _ => panic!("wrong variant"),
        }
        // Batched right-hand sides decode as a block query.
        match decode(r#"{"cmd":"query","model":1,"nu":0.5,"bs":[[1.0,2.0],[3.0,4.0]]}"#).unwrap()
        {
            Request::Query { bs, .. } => {
                assert_eq!(bs, Some(vec![vec![1.0, 2.0], vec![3.0, 4.0]]))
            }
            _ => panic!("wrong variant"),
        }
        match decode(r#"{"cmd":"predict","model":2,"nu":1.5,"rows":[[1.0,2.0],[3.0,4.0]]}"#)
            .unwrap()
        {
            Request::Predict { model, nu, rows, .. } => {
                assert_eq!(model, 2);
                assert_eq!(nu, 1.5);
                assert_eq!(rows, vec![vec![1.0, 2.0], vec![3.0, 4.0]]);
            }
            _ => panic!("wrong variant"),
        }
        assert!(matches!(decode(r#"{"cmd":"evict","model":4}"#).unwrap(),
            Request::Evict { model: 4, purge: false }));
        assert!(matches!(decode(r#"{"cmd":"models"}"#).unwrap(), Request::Models));
        // Malformed registry requests.
        assert!(decode(r#"{"cmd":"query"}"#).is_err(), "missing model id");
        assert!(decode(r#"{"cmd":"query","model":1,"b":[1.0],"nus":[1.0,0.1]}"#).is_err());
        assert!(decode(r#"{"cmd":"query","model":1,"b":["x"]}"#).is_err());
        // Malformed batches: empty, non-array values/entries, non-finite
        // values, or combined with the exclusive forms. A present "bs"
        // must NEVER silently degrade to a plain (state-mutating) solve.
        assert!(decode(r#"{"cmd":"query","model":1,"bs":[]}"#).is_err(), "empty batch");
        assert!(decode(r#"{"cmd":"query","model":1,"bs":[1.0]}"#).is_err());
        assert!(decode(r#"{"cmd":"query","model":1,"bs":"[[1.0]]"}"#).is_err(), "string bs");
        assert!(decode(r#"{"cmd":"query","model":1,"bs":5}"#).is_err(), "scalar bs");
        assert!(decode(r#"{"cmd":"query","model":1,"bs":[["x"]]}"#).is_err());
        assert!(decode(r#"{"cmd":"query","model":1,"bs":[[1.0]],"b":[1.0]}"#).is_err());
        assert!(decode(r#"{"cmd":"query","model":1,"bs":[[1.0]],"nus":[1.0,0.1]}"#).is_err());
        // Same strictness for the scalar forms: a present-but-non-array
        // "b" or "nus" is an error, not an ignored field.
        assert!(decode(r#"{"cmd":"query","model":1,"b":"[1.0]"}"#).is_err());
        assert!(decode(r#"{"cmd":"query","model":1,"nus":1.0}"#).is_err());
        // But JSON null unambiguously means absent (serializers emit it
        // for unset optionals) and keeps the old behavior.
        match decode(r#"{"cmd":"query","model":1,"b":null,"bs":null,"nus":null}"#).unwrap() {
            Request::Query { b, bs, nus, .. } => {
                assert!(b.is_none() && bs.is_none() && nus.is_empty());
            }
            _ => panic!("wrong variant"),
        }
        // Non-numeric path entries are an error, not a silent single solve.
        assert!(decode(r#"{"cmd":"query","model":1,"nus":["10","1"]}"#).is_err());
        assert!(decode(r#"{"cmd":"solve","nus":[10,"1",0.1]}"#).is_err());
        assert!(decode(r#"{"cmd":"predict","model":1}"#).is_err(), "missing rows");
        assert!(decode(r#"{"cmd":"predict","model":1,"rows":[]}"#).is_err());
        assert!(decode(r#"{"cmd":"predict","model":1,"rows":[1.0]}"#).is_err());
        assert!(decode(r#"{"cmd":"evict"}"#).is_err());
        // Ids must be non-negative integers — no silent truncation onto a
        // different model.
        assert!(decode(r#"{"cmd":"query","model":1.9}"#).is_err());
        assert!(decode(r#"{"cmd":"evict","model":-1}"#).is_err());
        assert!(decode(r#"{"cmd":"status","job":2.5}"#).is_err());
    }

    #[test]
    fn decode_append() {
        let line = r#"{"cmd":"append","model":7,"rows":2,"cols":2,
                       "triplets":[[0,0,1.0],[1,1,2.0]],"b":[0.5,0.25]}"#;
        match decode(&line.replace('\n', " ")).unwrap() {
            Request::Append { model, a, b, eager, .. } => {
                assert_eq!(model, 7);
                assert!(a.is_sparse());
                assert_eq!((a.rows(), a.cols(), a.nnz()), (2, 2, 2));
                assert_eq!(b, vec![0.5, 0.25]);
                assert!(eager, "refresh defaults to eager");
            }
            _ => panic!("wrong variant"),
        }
        let lazy = r#"{"cmd":"append","model":7,"rows":1,"cols":2,
                       "triplets":[[0,1,3.0]],"b":[1.0],"refresh":"lazy"}"#;
        match decode(&lazy.replace('\n', " ")).unwrap() {
            Request::Append { eager, .. } => assert!(!eager),
            _ => panic!("wrong variant"),
        }
        // Missing pieces and malformed payloads are rejected outright.
        assert!(
            decode(r#"{"cmd":"append","rows":1,"cols":1,"triplets":[[0,0,1.0]],"b":[1.0]}"#)
                .is_err(),
            "missing model id"
        );
        assert!(decode(r#"{"cmd":"append","model":7}"#).is_err(), "missing triplets");
        assert!(
            decode(r#"{"cmd":"append","model":7,"profile":"exp","n":8,"d":2}"#).is_err(),
            "synthetic profiles are not appendable"
        );
        assert!(
            decode(r#"{"cmd":"append","model":7,"cols":2,"triplets":[[0,0,1.0]],"b":[1.0]}"#)
                .is_err(),
            "missing rows"
        );
        assert!(
            decode(
                r#"{"cmd":"append","model":7,"rows":2,"cols":2,"triplets":[[0,0,1.0]],"b":[1.0]}"#
            )
            .is_err(),
            "b length must equal rows"
        );
        // A present-but-unknown refresh policy is an error, never a
        // silent eager refresh; null means absent as everywhere else.
        assert!(decode(
            r#"{"cmd":"append","model":7,"rows":1,"cols":1,"triplets":[[0,0,1.0]],"b":[1.0],"refresh":"sometime"}"#
        )
        .is_err());
        assert!(decode(
            r#"{"cmd":"append","model":7,"rows":1,"cols":1,"triplets":[[0,0,1.0]],"b":[1.0],"refresh":7}"#
        )
        .is_err());
        match decode(
            r#"{"cmd":"append","model":7,"rows":1,"cols":1,"triplets":[[0,0,1.0]],"b":[1.0],"refresh":null}"#
        )
        .unwrap()
        {
            Request::Append { eager, .. } => assert!(eager),
            _ => panic!("wrong variant"),
        }
    }

    #[test]
    fn decode_evict_purge_and_snapshot() {
        assert!(matches!(
            decode(r#"{"cmd":"evict","model":2,"purge":true}"#).unwrap(),
            Request::Evict { model: 2, purge: true }
        ));
        assert!(matches!(
            decode(r#"{"cmd":"evict","model":2,"purge":null}"#).unwrap(),
            Request::Evict { model: 2, purge: false }
        ));
        // A present-but-non-bool purge is an error, never a silent spill.
        assert!(decode(r#"{"cmd":"evict","model":2,"purge":"yes"}"#).is_err());
        assert!(decode(r#"{"cmd":"evict","model":2,"purge":1}"#).is_err());
        assert!(matches!(
            decode(r#"{"cmd":"snapshot"}"#).unwrap(),
            Request::Snapshot { model: None }
        ));
        assert!(matches!(
            decode(r#"{"cmd":"snapshot","model":null}"#).unwrap(),
            Request::Snapshot { model: None }
        ));
        assert!(matches!(
            decode(r#"{"cmd":"snapshot","model":5}"#).unwrap(),
            Request::Snapshot { model: Some(5) }
        ));
        // A present-but-bad model id is rejected, not ignored — snapshot
        // of "model 1.5" must not silently become snapshot-everything.
        assert!(decode(r#"{"cmd":"snapshot","model":1.5}"#).is_err());
        assert!(decode(r#"{"cmd":"snapshot","model":"all"}"#).is_err());
    }

    #[test]
    fn decode_path_solve() {
        let r = decode(r#"{"cmd":"solve","profile":"exp","nus":[10,1,0.1]}"#).unwrap();
        match r {
            Request::Solve(spec) => assert_eq!(spec.path_nus, vec![10.0, 1.0, 0.1]),
            _ => panic!("wrong variant"),
        }
    }

    #[test]
    fn decode_invalid_nu_eps_rejected_at_the_wire() {
        // Non-positive / non-finite regularization or tolerance never
        // reaches a solver — the decode answers a structured error.
        for bad in ["0", "-1.0", "1e999", "\"x\""] {
            let line = format!(r#"{{"cmd":"query","model":1,"nu":{bad}}}"#);
            let e = decode(&line).unwrap_err();
            assert!(e.starts_with("invalid nu"), "nu={bad}: {e}");
            let line = format!(r#"{{"cmd":"solve","nu":{bad}}}"#);
            assert!(decode(&line).unwrap_err().starts_with("invalid nu"));
            let line = format!(r#"{{"cmd":"predict","model":1,"rows":[[1.0]],"nu":{bad}}}"#);
            assert!(decode(&line).unwrap_err().starts_with("invalid nu"));
        }
        for bad in ["0", "-1e-9", "1e999"] {
            let line = format!(r#"{{"cmd":"query","model":1,"eps":{bad}}}"#);
            assert!(decode(&line).unwrap_err().starts_with("invalid eps"), "eps={bad}");
        }
        // Path entries get the same treatment.
        assert!(decode(r#"{"cmd":"query","model":1,"nus":[1.0,-0.5]}"#)
            .unwrap_err()
            .starts_with("invalid nu"));
        // null means absent and keeps the defaults.
        match decode(r#"{"cmd":"query","model":1,"nu":null,"eps":null}"#).unwrap() {
            Request::Query { nu, eps, .. } => {
                assert_eq!(nu, 1.0);
                assert_eq!(eps, 1e-8);
            }
            _ => panic!("wrong variant"),
        }
    }

    #[test]
    fn decode_deadline_s() {
        match decode(r#"{"cmd":"query","model":1,"deadline_s":2.5}"#).unwrap() {
            Request::Query { deadline_s, .. } => assert_eq!(deadline_s, Some(2.5)),
            _ => panic!("wrong variant"),
        }
        match decode(
            r#"{"cmd":"append","model":1,"rows":1,"cols":1,"triplets":[[0,0,1.0]],"b":[1.0],"deadline_s":1}"#,
        )
        .unwrap()
        {
            Request::Append { deadline_s, .. } => assert_eq!(deadline_s, Some(1.0)),
            _ => panic!("wrong variant"),
        }
        match decode(r#"{"cmd":"predict","model":1,"rows":[[1.0]],"deadline_s":null}"#).unwrap() {
            Request::Predict { deadline_s, .. } => assert!(deadline_s.is_none()),
            _ => panic!("wrong variant"),
        }
        for bad in ["0", "-3", "1e999", "\"soon\""] {
            let line = format!(r#"{{"cmd":"query","model":1,"deadline_s":{bad}}}"#);
            assert!(decode(&line).unwrap_err().starts_with("invalid deadline_s"), "{bad}");
        }
    }

    #[test]
    fn decode_control_commands() {
        assert!(matches!(decode(r#"{"cmd":"ping"}"#).unwrap(), Request::Ping));
        assert!(matches!(decode(r#"{"cmd":"metrics"}"#).unwrap(), Request::Metrics));
        assert!(matches!(decode(r#"{"cmd":"health"}"#).unwrap(), Request::Health));
        assert!(matches!(decode(r#"{"cmd":"shutdown"}"#).unwrap(), Request::Shutdown));
        assert!(matches!(
            decode(r#"{"cmd":"wait","job":3,"timeout_s":5}"#).unwrap(),
            Request::Wait { job: 3, .. }
        ));
    }

    #[test]
    fn decode_errors() {
        assert!(decode("not json").is_err());
        assert!(decode(r#"{"cmd":"status"}"#).is_err(), "missing job id");
        assert!(decode(r#"{"cmd":"explode"}"#).is_err());
        assert!(decode(r#"{"cmd":"solve","solver":"bogus"}"#).is_err());
    }

    #[test]
    fn response_encoding() {
        let line = ok(vec![("job", Json::from(3usize))]);
        let v = json::parse(&line).unwrap();
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("job").unwrap().as_usize(), Some(3));
        let e = err("boom");
        let v = json::parse(&e).unwrap();
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(false));
        assert_eq!(v.get("error").unwrap().as_str(), Some("boom"));
    }
}
