//! Process-wide metrics: counters plus a streaming latency aggregate.

use crate::util::json::Json;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Counters are lock-free; the latency aggregate takes a short mutex.
#[derive(Debug)]
pub struct Metrics {
    /// Jobs accepted onto the queue.
    pub submitted: AtomicU64,
    /// Jobs finished successfully.
    pub completed: AtomicU64,
    /// Jobs that errored or panicked.
    pub failed: AtomicU64,
    /// Submissions rejected by backpressure.
    pub rejected: AtomicU64,
    solve_time: Mutex<LatencyAgg>,
}

#[derive(Debug, Default, Clone, Copy)]
struct LatencyAgg {
    count: u64,
    sum: f64,
    sum_sq: f64,
    min: f64,
    max: f64,
}

impl Metrics {
    /// Fresh zeroed metrics.
    pub fn new() -> Self {
        Self {
            submitted: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            failed: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            solve_time: Mutex::new(LatencyAgg::default()),
        }
    }

    /// Record one solve latency (seconds).
    pub fn record_solve_time(&self, seconds: f64) {
        let mut agg = self.solve_time.lock().unwrap();
        if agg.count == 0 {
            agg.min = seconds;
            agg.max = seconds;
        } else {
            agg.min = agg.min.min(seconds);
            agg.max = agg.max.max(seconds);
        }
        agg.count += 1;
        agg.sum += seconds;
        agg.sum_sq += seconds * seconds;
    }

    /// Mean solve latency (0 if none recorded).
    pub fn mean_solve_time(&self) -> f64 {
        let agg = self.solve_time.lock().unwrap();
        if agg.count == 0 {
            0.0
        } else {
            agg.sum / agg.count as f64
        }
    }

    /// JSON snapshot for the `metrics` wire command.
    pub fn to_json(&self) -> Json {
        let agg = *self.solve_time.lock().unwrap();
        let mean = if agg.count > 0 { agg.sum / agg.count as f64 } else { 0.0 };
        let var = if agg.count > 1 {
            (agg.sum_sq - agg.sum * agg.sum / agg.count as f64) / (agg.count as f64 - 1.0)
        } else {
            0.0
        };
        Json::obj(vec![
            ("submitted", Json::from(self.submitted.load(Ordering::Relaxed) as usize)),
            ("completed", Json::from(self.completed.load(Ordering::Relaxed) as usize)),
            ("failed", Json::from(self.failed.load(Ordering::Relaxed) as usize)),
            ("rejected", Json::from(self.rejected.load(Ordering::Relaxed) as usize)),
            ("solve_time_mean_s", Json::from(mean)),
            ("solve_time_std_s", Json::from(var.max(0.0).sqrt())),
            ("solve_time_min_s", Json::from(agg.min)),
            ("solve_time_max_s", Json::from(agg.max)),
            ("solve_count", Json::from(agg.count as usize)),
        ])
    }
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_start_at_zero() {
        let m = Metrics::new();
        let j = m.to_json();
        assert_eq!(j.get("submitted").unwrap().as_usize(), Some(0));
        assert_eq!(m.mean_solve_time(), 0.0);
    }

    #[test]
    fn latency_aggregation() {
        let m = Metrics::new();
        for t in [0.1, 0.2, 0.3] {
            m.record_solve_time(t);
        }
        assert!((m.mean_solve_time() - 0.2).abs() < 1e-12);
        let j = m.to_json();
        assert_eq!(j.get("solve_count").unwrap().as_usize(), Some(3));
        assert!((j.get("solve_time_min_s").unwrap().as_f64().unwrap() - 0.1).abs() < 1e-12);
        assert!((j.get("solve_time_max_s").unwrap().as_f64().unwrap() - 0.3).abs() < 1e-12);
        let std = j.get("solve_time_std_s").unwrap().as_f64().unwrap();
        assert!((std - 0.1).abs() < 1e-9, "std {std}");
    }

    #[test]
    fn counters_are_atomic() {
        let m = std::sync::Arc::new(Metrics::new());
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let m = m.clone();
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        m.submitted.fetch_add(1, Ordering::Relaxed);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(m.submitted.load(Ordering::Relaxed), 4000);
    }
}
