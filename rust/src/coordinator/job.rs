//! Job model: what a client may ask the coordinator to solve, and the
//! lifecycle of a submitted job.

use crate::data::synthetic::{self, SpectrumProfile};
use crate::linalg::Operand;
use crate::solvers::api::{Solver as _, SolverSpec};
use crate::solvers::{RidgeProblem, SolveReport};
use crate::util::json::Json;

/// Monotonic job identifier.
pub type JobId = u64;

/// Default density for the bare `"sparse"` profile.
pub const DEFAULT_SPARSE_DENSITY: f64 = 0.01;

/// The data a job runs on. Workloads are generated server-side from a
/// spec (shipping an 8k x 1k matrix over the wire would dwarf solve time;
/// the spec is also what makes runs reproducible) — except for
/// small-payload inline CSR jobs, which the wire protocol accepts as
/// triplets (`"triplets"` / `"rows"` / `"cols"` / `"b"` request fields).
#[derive(Clone, Debug, PartialEq)]
pub enum Workload {
    /// Synthetic dataset with a named profile (see [`crate::data`]):
    /// `exp`, `poly`, `mnist-like`, `cifar-like`, `exp:<rate>`, plus the
    /// density-controlled CSR profiles `sparse` (1% dense) and
    /// `sparse:<density>`.
    Synthetic { profile: String, n: usize, d: usize, seed: u64 },
    /// Raw problem (dense or CSR) supplied in-process by library users or
    /// decoded from inline triplets on the wire.
    Inline { a: Operand, b: Vec<f64> },
}

impl Workload {
    /// Materialize the data operand and observations.
    pub fn materialize(&self) -> Result<(Operand, Vec<f64>), String> {
        match self {
            Workload::Inline { a, b } => Ok((a.clone(), b.clone())),
            Workload::Synthetic { profile, n, d, seed } => {
                let ds = match profile.as_str() {
                    "exp" => synthetic::exponential_decay(*n, *d, *seed),
                    "poly" => synthetic::polynomial_decay(*n, *d, *seed),
                    "mnist-like" => synthetic::mnist_like(*n, *d, *seed),
                    "cifar-like" => synthetic::cifar_like(*n, *d, *seed),
                    "sparse" => synthetic::sparse_gaussian(*n, *d, DEFAULT_SPARSE_DENSITY, *seed),
                    other => {
                        if let Some(rate) = other.strip_prefix("exp:") {
                            let rate: f64 = rate.parse().map_err(|_| format!("bad rate in {other}"))?;
                            synthetic::generate(*n, *d, &SpectrumProfile::Exponential { rate }, *seed, other)
                        } else if let Some(dens) = other.strip_prefix("sparse:") {
                            let dens: f64 =
                                dens.parse().map_err(|_| format!("bad density in {other}"))?;
                            if !(dens > 0.0 && dens <= 1.0) {
                                return Err(format!("density must be in (0, 1], got {dens}"));
                            }
                            synthetic::sparse_gaussian(*n, *d, dens, *seed)
                        } else {
                            return Err(format!("unknown workload profile: {other}"));
                        }
                    }
                };
                Ok((ds.a, ds.b))
            }
        }
    }
}

/// A full job specification. The solver is a [`SolverSpec`]: any string
/// accepted by `SolverSpec::from_str` (see `effdim solvers` for the
/// registry) is a valid job solver — the coordinator carries no solver
/// dispatch of its own.
#[derive(Clone, Debug)]
pub struct JobSpec {
    /// The data to solve on.
    pub workload: Workload,
    /// Regularization level.
    pub nu: f64,
    /// Solver spec string resolved at decode time.
    pub solver: SolverSpec,
    /// Relative precision target; measured against the direct solution
    /// (the coordinator computes the oracle, mirroring the paper's
    /// experimental protocol).
    pub eps: f64,
    /// Seed for the solver's sketch stream.
    pub seed: u64,
    /// Non-empty: run a warm-started regularization path over these
    /// (strictly decreasing) nu values instead of the single solve at
    /// `nu` — the Figure-1 workload as a service.
    pub path_nus: Vec<f64>,
    /// Pin the parallel dense kernels to this many threads for the whole
    /// job (oracle solve included). `None` = ambient default; a
    /// `@threads=k` param on the solver spec still overrides during the
    /// solver's own `solve` call.
    pub threads: Option<usize>,
}

/// Lifecycle states. Jobs only ever move forward.
#[derive(Clone, Debug)]
pub enum JobState {
    /// Accepted, waiting for a worker.
    Queued,
    /// Executing on a worker.
    Running,
    /// Finished successfully.
    Done(Box<SolveOutcome>),
    /// Finished with an error (message preserved).
    Failed(String),
}

impl JobState {
    /// Wire label: `queued` / `running` / `done` / `failed`.
    pub fn label(&self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Running => "running",
            JobState::Done(_) => "done",
            JobState::Failed(_) => "failed",
        }
    }

    /// Whether the job can no longer change state.
    pub fn is_terminal(&self) -> bool {
        matches!(self, JobState::Done(_) | JobState::Failed(_))
    }
}

/// Result payload of a finished job.
#[derive(Clone, Debug)]
pub struct SolveOutcome {
    /// For path jobs: the report of the final path point (cumulative wall
    /// time in `wall_time_s`); per-point detail in `path_points`.
    pub report: SolveReport,
    /// Solution vector at the final point (returned on request).
    pub x: Vec<f64>,
    /// `(nu, cumulative_time_s, iterations, peak_m, converged)` per path
    /// point; empty for single solves.
    pub path_points: Vec<(f64, f64, usize, usize, bool)>,
}

/// Shared wire encoding of a [`SolveReport`] — the field set both job
/// results and registry query responses carry.
pub fn report_fields(r: &SolveReport) -> Vec<(&'static str, Json)> {
    let mut fields = vec![
        ("solver", Json::from(r.solver.clone())),
        ("iterations", Json::from(r.iterations)),
        ("rejections", Json::from(r.rejections)),
        ("doublings", Json::from(r.doublings)),
        ("final_m", Json::from(r.final_m)),
        ("peak_m", Json::from(r.peak_m)),
        ("wall_time_s", Json::from(r.wall_time_s)),
        ("sketch_time_s", Json::from(r.sketch_time_s)),
        ("factor_time_s", Json::from(r.factor_time_s)),
        ("iter_time_s", Json::from(r.iter_time_s)),
        ("converged", Json::from(r.converged)),
        // Highest numerical-recovery rung the solve climbed: "none" for
        // healthy solves, else "jitter" / "resketch" / "exact".
        ("recovery", Json::from(r.recovery.label().to_string())),
    ];
    if let Some(e) = r.final_rel_error {
        fields.push(("final_rel_error", Json::from(e)));
    }
    fields
}

impl SolveOutcome {
    /// Wire representation (without the solution vector unless asked).
    pub fn to_json(&self, include_x: bool) -> Json {
        let mut fields = report_fields(&self.report);
        if include_x {
            fields.push(("x", Json::Arr(self.x.iter().map(|&v| Json::from(v)).collect())));
        }
        if !self.path_points.is_empty() {
            fields.push((
                "path",
                Json::Arr(
                    self.path_points
                        .iter()
                        .map(|&(nu, t, iters, m, conv)| {
                            Json::obj(vec![
                                ("nu", Json::from(nu)),
                                ("cum_time_s", Json::from(t)),
                                ("iterations", Json::from(iters)),
                                ("peak_m", Json::from(m)),
                                ("converged", Json::from(conv)),
                            ])
                        })
                        .collect(),
                ),
            ));
        }
        Json::obj(fields)
    }
}

/// Execute a job spec to completion (runs on a scheduler worker).
pub fn execute(spec: &JobSpec) -> Result<SolveOutcome, String> {
    match spec.threads {
        // The override is thread-local, so concurrent workers with
        // different settings cannot interfere.
        Some(k) => crate::linalg::threads::with_threads(k, || execute_inner(spec)),
        None => execute_inner(spec),
    }
}

fn execute_inner(spec: &JobSpec) -> Result<SolveOutcome, String> {
    let (a, b) = spec.workload.materialize()?;
    // Shape/solver compatibility: the dual reduction handles d >= n and
    // nothing else; every other solver needs n >= d.
    let is_dual = matches!(spec.solver, SolverSpec::DualAdaptive { .. });
    if a.rows() < a.cols() && !is_dual {
        return Err(format!(
            "underdetermined workload (n {} < d {}) needs a dual-adaptive-* solver",
            a.rows(),
            a.cols()
        ));
    }
    if is_dual && a.rows() > a.cols() {
        return Err(format!(
            "dual solvers need d >= n (workload is n {} x d {})",
            a.rows(),
            a.cols()
        ));
    }
    if !spec.path_nus.is_empty() {
        return execute_path(spec, &a, &b);
    }
    let problem = RidgeProblem::new(a, b, spec.nu);
    // Oracle for the stop rule (skipped for dual specs, which build their
    // own dual-space oracle — see SolverSpec::true_error_stop).
    let stop = spec.solver.true_error_stop(&problem, spec.eps);
    let x0 = vec![0.0; problem.d()];

    // `try_solve` so solver-side failure (invalid input, numerical
    // recovery exhausted, deadline) fails the job with a structured
    // message instead of unwinding through the worker.
    let solution = spec
        .solver
        .build(spec.seed)
        .try_solve(&problem, &x0, &stop)
        .map_err(String::from)?;
    Ok(SolveOutcome { report: solution.report, x: solution.x, path_points: Vec::new() })
}

/// Run a warm-started regularization path (Figure-1 workload) as one job.
fn execute_path(spec: &JobSpec, a: &Operand, b: &[f64]) -> Result<SolveOutcome, String> {
    use crate::solvers::path::run_path;
    for w in spec.path_nus.windows(2) {
        if w[0] <= w[1] {
            return Err("path nus must be strictly decreasing".into());
        }
    }
    let res = run_path(a, b, &spec.path_nus, spec.eps, &spec.solver, spec.seed);
    let path_points: Vec<(f64, f64, usize, usize, bool)> = res
        .points
        .iter()
        .map(|p| {
            (p.nu, p.cumulative_time_s, p.report.iterations, p.report.peak_m, p.report.converged)
        })
        .collect();
    let mut report = res.points.last().unwrap().report.clone();
    report.wall_time_s = res.total_time_s();
    report.peak_m = res.peak_m();
    report.converged = res.points.iter().all(|p| p.report.converged);
    report.solver = format!("path-{}", res.solver);
    Ok(SolveOutcome { report, x: Vec::new(), path_points })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(solver: &str) -> JobSpec {
        JobSpec {
            workload: Workload::Synthetic { profile: "exp".into(), n: 128, d: 16, seed: 1 },
            nu: 0.5,
            solver: solver.parse().unwrap(),
            eps: 1e-8,
            seed: 7,
            path_nus: Vec::new(),
            threads: None,
        }
    }

    #[test]
    fn execute_honors_job_threads() {
        let mut sp = spec("adaptive-srht");
        sp.threads = Some(2);
        let pinned = execute(&sp).unwrap();
        assert!(pinned.report.converged);
        // Same job without the pin produces the same solution (the knob
        // changes scheduling, not semantics).
        sp.threads = None;
        let free = execute(&sp).unwrap();
        assert_eq!(pinned.report.iterations, free.report.iterations);
    }

    #[test]
    fn execute_adaptive_job() {
        let out = execute(&spec("adaptive")).unwrap();
        assert!(out.report.converged);
        assert_eq!(out.x.len(), 16);
    }

    #[test]
    fn execute_cg_and_pcg_jobs() {
        assert!(execute(&spec("cg")).unwrap().report.converged);
        assert!(execute(&spec("pcg-srht")).unwrap().report.converged);
    }

    #[test]
    fn execute_direct_and_ihs_jobs() {
        // The coordinator accepts every spec string, not a hardcoded menu.
        let direct_out = execute(&spec("direct")).unwrap();
        assert!(direct_out.report.converged);
        assert_eq!(direct_out.report.solver, "direct");
        let ihs_out = execute(&spec("ihs-gaussian@m=64")).unwrap();
        assert!(ihs_out.report.converged);
        assert_eq!(ihs_out.report.solver, "ihs-gaussian@m=64");
    }

    #[test]
    fn dual_solver_rejected_on_tall_workload() {
        let err = execute(&spec("dual-adaptive-gaussian")).unwrap_err();
        assert!(err.contains("dual solvers need d >= n"), "{err}");
    }

    #[test]
    fn dual_solver_runs_on_wide_inline_workload() {
        // The dual spec exists for d >= n; an inline wide workload must
        // execute, and a non-dual solver on the same data must be refused.
        let ds = crate::data::synthetic::exponential_decay(64, 16, 5);
        let a = ds.a.transpose(); // 16 x 64
        let b = ds.b[..16].to_vec();
        let mut sp = spec("dual-adaptive-gaussian");
        sp.workload = Workload::Inline { a: a.clone(), b: b.clone() };
        let out = execute(&sp).unwrap();
        assert!(out.report.converged);
        assert_eq!(out.report.solver, "dual-adaptive-gaussian");
        assert_eq!(out.x.len(), 64);

        let mut cg_sp = spec("cg");
        cg_sp.workload = Workload::Inline { a, b };
        let err = execute(&cg_sp).unwrap_err();
        assert!(err.contains("dual-adaptive"), "{err}");
    }

    #[test]
    fn workload_profiles_materialize() {
        for p in ["exp", "poly", "mnist-like", "cifar-like", "exp:0.9", "sparse", "sparse:0.2"] {
            let w = Workload::Synthetic { profile: p.into(), n: 64, d: 8, seed: 2 };
            let (a, b) = w.materialize().unwrap();
            assert_eq!((a.rows(), a.cols(), b.len()), (64, 8, 64), "{p}");
            assert_eq!(a.is_sparse(), p.starts_with("sparse"), "{p}");
        }
        for bad in ["nope", "sparse:0", "sparse:2", "sparse:x"] {
            let w = Workload::Synthetic { profile: bad.into(), n: 64, d: 8, seed: 2 };
            assert!(w.materialize().is_err(), "{bad} should be rejected");
        }
    }

    #[test]
    fn sparse_profile_job_executes_end_to_end() {
        // A CSR-backed synthetic job runs through the same unified
        // dispatch as everything else (0.3 keeps the tiny 64 x 8 matrix
        // full-rank with overwhelming probability).
        let mut sp = spec("adaptive-sparse");
        sp.workload = Workload::Synthetic { profile: "sparse:0.3".into(), n: 64, d: 8, seed: 3 };
        let out = execute(&sp).unwrap();
        assert!(out.report.converged);
        assert_eq!(out.x.len(), 8);
    }

    #[test]
    fn outcome_json_shape() {
        let out = execute(&spec("adaptive")).unwrap();
        let j = out.to_json(false);
        assert!(j.get("iterations").is_some());
        assert!(j.get("x").is_none());
        assert_eq!(j.get("recovery").unwrap().as_str().unwrap(), "none");
        let jx = out.to_json(true);
        assert_eq!(jx.get("x").unwrap().as_arr().unwrap().len(), 16);
    }

    #[test]
    fn execute_path_job() {
        let mut sp = spec("adaptive-srht");
        sp.path_nus = vec![10.0, 1.0, 0.1];
        let out = execute(&sp).unwrap();
        assert!(out.report.converged);
        assert_eq!(out.path_points.len(), 3);
        assert!(out.report.solver.starts_with("path-"));
        // Cumulative times monotone.
        for w in out.path_points.windows(2) {
            assert!(w[1].1 >= w[0].1);
        }
        let j = out.to_json(false);
        assert_eq!(j.get("path").unwrap().as_arr().unwrap().len(), 3);
        // Unsorted path rejected.
        sp.path_nus = vec![0.1, 1.0];
        assert!(execute(&sp).is_err());
    }

    #[test]
    fn state_labels() {
        assert_eq!(JobState::Queued.label(), "queued");
        assert!(!JobState::Running.is_terminal());
        assert!(JobState::Failed("x".into()).is_terminal());
    }
}
