//! TCP front end: `std::net` listener, one thread per connection,
//! line-delimited JSON (see [`super::protocol`]).

use super::job::JobState;
use super::protocol::{self, Request};
use super::scheduler::Scheduler;
use crate::util::json::Json;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// The coordinator server. Owns the scheduler.
pub struct Server {
    scheduler: Arc<Scheduler>,
    listener: TcpListener,
    stop: Arc<AtomicBool>,
}

impl Server {
    /// Bind to `addr` (e.g. `"127.0.0.1:0"` for an ephemeral port) with a
    /// worker pool of the given size.
    pub fn bind(addr: &str, workers: usize) -> std::io::Result<Self> {
        let listener = TcpListener::bind(addr)?;
        // Poll for shutdown between accepts.
        listener.set_nonblocking(true)?;
        Ok(Self {
            scheduler: Arc::new(Scheduler::start(workers, 256)),
            listener,
            stop: Arc::new(AtomicBool::new(false)),
        })
    }

    /// Bound address (for clients when using an ephemeral port).
    pub fn local_addr(&self) -> std::net::SocketAddr {
        self.listener.local_addr().expect("bound listener")
    }

    /// Handle returned to request a stop from another thread.
    pub fn stop_handle(&self) -> Arc<AtomicBool> {
        Arc::clone(&self.stop)
    }

    /// Accept loop. Returns when `shutdown` is requested (via command or
    /// the stop handle).
    pub fn run(&self) {
        let mut conns: Vec<std::thread::JoinHandle<()>> = Vec::new();
        while !self.stop.load(Ordering::SeqCst) {
            match self.listener.accept() {
                Ok((stream, _addr)) => {
                    let scheduler = Arc::clone(&self.scheduler);
                    let stop = Arc::clone(&self.stop);
                    conns.push(std::thread::spawn(move || {
                        handle_connection(stream, &scheduler, &stop);
                    }));
                }
                Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(10));
                }
                Err(_) => break,
            }
            conns.retain(|h| !h.is_finished());
        }
        for h in conns {
            let _ = h.join();
        }
    }
}

fn handle_connection(stream: TcpStream, scheduler: &Scheduler, stop: &AtomicBool) {
    // Short read timeout so the thread re-checks the stop flag instead of
    // blocking forever on an idle client (run() joins these threads at
    // shutdown; an indefinite blocking read would deadlock the server).
    let _ = stream.set_read_timeout(Some(Duration::from_millis(200)));
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    loop {
        if stop.load(Ordering::SeqCst) {
            return;
        }
        match reader.read_line(&mut line) {
            Ok(0) => return, // client closed
            Ok(_) => {}
            Err(ref e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                // Timeout may leave a partial line buffered in `line`;
                // keep it and retry.
                continue;
            }
            Err(_) => return,
        }
        let request = std::mem::take(&mut line);
        if request.trim().is_empty() {
            continue;
        }
        let response = match protocol::decode(&request) {
            Err(e) => protocol::err(&e),
            Ok(req) => respond(req, scheduler, stop),
        };
        if writer.write_all(response.as_bytes()).is_err()
            || writer.write_all(b"\n").is_err()
            || writer.flush().is_err()
        {
            return;
        }
    }
}

fn respond(req: Request, scheduler: &Scheduler, stop: &AtomicBool) -> String {
    match req {
        Request::Ping => protocol::ok(vec![("pong", Json::Bool(true))]),
        Request::Metrics => protocol::ok(vec![
            ("metrics", scheduler.metrics().to_json()),
            ("backlog", Json::from(scheduler.backlog())),
        ]),
        Request::Solvers => {
            let entries = crate::solvers::api::registry()
                .into_iter()
                .map(|spec| {
                    Json::obj(vec![
                        ("spec", Json::from(spec.to_string())),
                        ("description", Json::from(spec.describe())),
                    ])
                })
                .collect();
            protocol::ok(vec![("solvers", Json::Arr(entries))])
        }
        Request::Shutdown => {
            stop.store(true, Ordering::SeqCst);
            protocol::ok(vec![("stopping", Json::Bool(true))])
        }
        Request::Solve(spec) => match scheduler.submit(spec) {
            Ok(id) => protocol::ok(vec![("job", Json::from(id as usize))]),
            Err(e) => protocol::err(&e.to_string()),
        },
        Request::Status { job } => match scheduler.status(job) {
            None => protocol::err("unknown job"),
            Some(state) => protocol::ok(vec![("state", Json::from(state.label()))]),
        },
        Request::Wait { job, timeout_s } => {
            match scheduler.wait(job, Duration::from_secs_f64(timeout_s.max(0.0))) {
                None => protocol::err("unknown job"),
                Some(state) => state_response(state, false),
            }
        }
        Request::Result { job, include_x } => match scheduler.status(job) {
            None => protocol::err("unknown job"),
            Some(state) => state_response(state, include_x),
        },
    }
}

fn state_response(state: JobState, include_x: bool) -> String {
    match state {
        JobState::Done(outcome) => protocol::ok(vec![
            ("state", Json::from("done")),
            ("result", outcome.to_json(include_x)),
        ]),
        JobState::Failed(msg) => protocol::ok(vec![
            ("state", Json::from("failed")),
            ("error", Json::from(msg)),
        ]),
        other => protocol::ok(vec![("state", Json::from(other.label()))]),
    }
}

/// Minimal blocking client for tests, examples, and the CLI.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: std::net::SocketAddr) -> std::io::Result<Self> {
        let stream = TcpStream::connect(addr)?;
        let writer = stream.try_clone()?;
        Ok(Self { reader: BufReader::new(stream), writer })
    }

    /// Send one request line, read one response line, parse it.
    pub fn call(&mut self, request: &str) -> Result<Json, String> {
        self.writer
            .write_all(request.as_bytes())
            .and_then(|_| self.writer.write_all(b"\n"))
            .and_then(|_| self.writer.flush())
            .map_err(|e| e.to_string())?;
        let mut line = String::new();
        self.reader.read_line(&mut line).map_err(|e| e.to_string())?;
        crate::util::json::parse(line.trim()).map_err(|e| e.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn start_server() -> (std::net::SocketAddr, Arc<AtomicBool>, std::thread::JoinHandle<()>) {
        let server = Server::bind("127.0.0.1:0", 2).unwrap();
        let addr = server.local_addr();
        let stop = server.stop_handle();
        let handle = std::thread::spawn(move || server.run());
        (addr, stop, handle)
    }

    #[test]
    fn ping_and_metrics() {
        let (addr, stop, handle) = start_server();
        let mut client = Client::connect(addr).unwrap();
        let pong = client.call(r#"{"cmd":"ping"}"#).unwrap();
        assert_eq!(pong.get("ok").unwrap().as_bool(), Some(true));
        let metrics = client.call(r#"{"cmd":"metrics"}"#).unwrap();
        assert!(metrics.get("metrics").is_some());
        stop.store(true, Ordering::SeqCst);
        handle.join().unwrap();
    }

    #[test]
    fn solve_roundtrip_over_tcp() {
        let (addr, stop, handle) = start_server();
        let mut client = Client::connect(addr).unwrap();
        let resp = client
            .call(r#"{"cmd":"solve","profile":"exp","n":128,"d":16,"nu":0.5,"solver":"adaptive","eps":1e-8,"seed":3}"#)
            .unwrap();
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true));
        let job = resp.get("job").unwrap().as_usize().unwrap();
        let done = client
            .call(&format!(r#"{{"cmd":"wait","job":{job},"timeout_s":60}}"#))
            .unwrap();
        assert_eq!(done.get("state").unwrap().as_str(), Some("done"));
        let result = done.get("result").unwrap();
        assert_eq!(result.get("converged").unwrap().as_bool(), Some(true));
        stop.store(true, Ordering::SeqCst);
        handle.join().unwrap();
    }

    #[test]
    fn solvers_command_lists_registry() {
        let (addr, stop, handle) = start_server();
        let mut client = Client::connect(addr).unwrap();
        let resp = client.call(r#"{"cmd":"solvers"}"#).unwrap();
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true));
        let listed = resp.get("solvers").unwrap().as_arr().unwrap();
        let registry = crate::solvers::api::registry();
        assert_eq!(listed.len(), registry.len());
        for (entry, spec) in listed.iter().zip(&registry) {
            assert_eq!(entry.get("spec").unwrap().as_str(), Some(spec.to_string().as_str()));
        }
        stop.store(true, Ordering::SeqCst);
        handle.join().unwrap();
    }

    #[test]
    fn malformed_requests_get_errors() {
        let (addr, stop, handle) = start_server();
        let mut client = Client::connect(addr).unwrap();
        let resp = client.call("garbage").unwrap();
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false));
        let resp = client.call(r#"{"cmd":"status","job":12345}"#).unwrap();
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false));
        stop.store(true, Ordering::SeqCst);
        handle.join().unwrap();
    }

    #[test]
    fn shutdown_command_stops_server() {
        let (addr, _stop, handle) = start_server();
        let mut client = Client::connect(addr).unwrap();
        let resp = client.call(r#"{"cmd":"shutdown"}"#).unwrap();
        assert_eq!(resp.get("stopping").unwrap().as_bool(), Some(true));
        handle.join().unwrap();
    }
}
