//! TCP front end: `std::net` listener, one thread per connection,
//! line-delimited JSON (see [`super::protocol`]).
//!
//! # Hardening
//!
//! The listener is built to keep answering structured errors under abuse
//! and faults rather than hanging, leaking, or dying:
//!
//! * **Request-line cap** — a connection may never buffer more than
//!   [`ServerConfig::max_line_bytes`] (default 16 MiB) of a single line;
//!   an overlong request answers `{"ok":false,"error":"request too
//!   large: ..."}` and the connection closes (resync mid-line is not
//!   possible).
//! * **Bounded connections** — at most [`ServerConfig::max_conns`]
//!   concurrent connection threads; an accept beyond that is *shed* with
//!   `{"ok":false,"error":"overloaded","retry_after_s":..}` instead of
//!   queueing unboundedly.
//! * **Wall deadlines** — registry requests honor a per-request
//!   `"deadline_s"` (or the server-wide [`ServerConfig::request_timeout`]
//!   default): a solve that runs past it rolls the session back and
//!   answers a `"deadline exceeded: ..."` error.
//! * **Graceful drain** — a `shutdown` command (or the stop handle) stops
//!   accepting, lets in-flight requests finish writing their response,
//!   and joins every connection thread before `run` returns.
//! * **Fault injection** — `bind` arms [`crate::util::failpoint`] sites
//!   from `EFFDIM_FAILPOINTS`, so the chaos suite can drive breakdowns
//!   through a real server process deterministically.

use super::job::JobState;
use super::protocol::{self, Request};
use super::registry::{Registry, DEFAULT_BYTE_BUDGET};
use super::scheduler::Scheduler;
use crate::persist::{DurabilityPolicy, Store};
use crate::solvers::adaptive::FrozenOutcome;
use crate::util::failpoint;
use crate::util::json::Json;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// How long the nonblocking accept loop sleeps between polls. Bounds both
/// the shutdown latency (a `shutdown` command or stop-handle store is
/// honored within one interval) and the idle-server wakeup rate; accepted
/// connections are never delayed by it beyond one interval.
pub const ACCEPT_POLL_INTERVAL: Duration = Duration::from_millis(10);

/// Default cap on one request (or response) line: 16 MiB, comfortably
/// above the largest legitimate inline-triplet payload while bounding
/// what a misbehaving client can make the server buffer.
pub const DEFAULT_MAX_LINE_BYTES: usize = 16 * 1024 * 1024;

/// Default bound on concurrent connection threads.
pub const DEFAULT_MAX_CONNS: usize = 64;

/// Default bound on in-flight *tagged* (pipelined) requests per
/// connection — admission control one level below
/// [`ServerConfig::max_conns`]: a single connection cannot fan out more
/// worker threads than this, no matter how many tagged lines it floods.
pub const DEFAULT_MAX_PIPELINE: usize = 16;

/// Retry hint (seconds) in the overload-shed response.
pub const RETRY_AFTER_S: f64 = 1.0;

/// Tunables for a server instance. `Default` gives the production
/// settings; tests shrink the limits to exercise the guard rails.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Scheduler worker threads for asynchronous `solve` jobs.
    pub workers: usize,
    /// Registry LRU byte budget across all registered models.
    pub model_byte_budget: usize,
    /// Per-connection cap on a single request line, in bytes.
    pub max_line_bytes: usize,
    /// Server-wide default wall deadline per registry request; a wire
    /// `"deadline_s"` overrides it per request. `None` = unlimited.
    pub request_timeout: Option<Duration>,
    /// Maximum concurrent connections before accepts are shed.
    pub max_conns: usize,
    /// Maximum in-flight pipelined (tagged) requests per connection; a
    /// tagged request beyond this is shed with a *tagged*
    /// `{"id":N,"ok":false,"error":"pipeline full","retry_after_s":..}`
    /// so the client knows exactly which request to retry. Untagged
    /// requests are unaffected (they are synchronous by contract).
    pub max_pipeline: usize,
    /// Durable state directory (`serve --state-dir`): registered models
    /// are snapshotted there, appends are WAL-logged, and startup
    /// recovers whatever a previous process left behind. `None` =
    /// RAM-only (the pre-durability behavior).
    pub state_dir: Option<PathBuf>,
    /// WAL fsync policy (`serve --durability strict|batch|off`); only
    /// meaningful with a `state_dir`.
    pub durability: DurabilityPolicy,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            workers: 2,
            model_byte_budget: DEFAULT_BYTE_BUDGET,
            max_line_bytes: DEFAULT_MAX_LINE_BYTES,
            request_timeout: None,
            max_conns: DEFAULT_MAX_CONNS,
            max_pipeline: DEFAULT_MAX_PIPELINE,
            state_dir: None,
            durability: DurabilityPolicy::Strict,
        }
    }
}

/// State shared by the accept loop and every connection thread.
struct Shared {
    scheduler: Scheduler,
    registry: Registry,
    stop: Arc<AtomicBool>,
    active_conns: AtomicUsize,
    config: ServerConfig,
}

/// The coordinator server. Owns the scheduler (async solve jobs) and the
/// model registry (synchronous register/query/predict traffic).
pub struct Server {
    shared: Arc<Shared>,
    listener: TcpListener,
}

impl Server {
    /// Bind to `addr` (e.g. `"127.0.0.1:0"` for an ephemeral port) with a
    /// worker pool of the given size and default limits.
    pub fn bind(addr: &str, workers: usize) -> std::io::Result<Self> {
        Self::bind_with_config(addr, ServerConfig { workers, ..ServerConfig::default() })
    }

    /// [`Server::bind`] with an explicit model-registry byte budget (the
    /// LRU eviction threshold across all registered models).
    pub fn bind_with_budget(
        addr: &str,
        workers: usize,
        model_byte_budget: usize,
    ) -> std::io::Result<Self> {
        Self::bind_with_config(
            addr,
            ServerConfig { workers, model_byte_budget, ..ServerConfig::default() },
        )
    }

    /// Bind with full control over the hardening knobs.
    pub fn bind_with_config(addr: &str, config: ServerConfig) -> std::io::Result<Self> {
        // Deterministic fault injection: a chaos harness arms sites for a
        // whole server process through the environment.
        failpoint::arm_from_env()
            .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidInput, e))?;
        // Durable serving: open the state dir and recover whatever a
        // previous process left behind *before* accepting traffic, so
        // recovered ids answer from the first request on.
        let invalid = |e: String| std::io::Error::new(std::io::ErrorKind::InvalidInput, e);
        let registry = match &config.state_dir {
            None => Registry::new(config.model_byte_budget),
            Some(dir) => {
                let store = Arc::new(Store::open(dir, config.durability).map_err(invalid)?);
                let registry = Registry::with_store(config.model_byte_budget, store);
                let recovered = registry.recover().map_err(invalid)?;
                if recovered > 0 {
                    eprintln!(
                        "recovered {recovered} model(s) from {}",
                        dir.display()
                    );
                }
                registry
            }
        };
        let listener = TcpListener::bind(addr)?;
        // Poll for shutdown between accepts.
        listener.set_nonblocking(true)?;
        Ok(Self {
            shared: Arc::new(Shared {
                scheduler: Scheduler::start(config.workers, 256),
                registry,
                stop: Arc::new(AtomicBool::new(false)),
                active_conns: AtomicUsize::new(0),
                config,
            }),
            listener,
        })
    }

    /// Bound address (for clients when using an ephemeral port).
    pub fn local_addr(&self) -> std::net::SocketAddr {
        self.listener.local_addr().expect("bound listener")
    }

    /// Handle returned to request a stop from another thread.
    pub fn stop_handle(&self) -> Arc<AtomicBool> {
        Arc::clone(&self.shared.stop)
    }

    /// Accept loop. Returns when `shutdown` is requested (via command or
    /// the stop handle), after draining in-flight connections.
    pub fn run(&self) {
        let mut conns: Vec<std::thread::JoinHandle<()>> = Vec::new();
        while !self.shared.stop.load(Ordering::SeqCst) {
            match self.listener.accept() {
                Ok((stream, _addr)) => {
                    conns.retain(|h| !h.is_finished());
                    if conns.len() >= self.shared.config.max_conns {
                        shed(stream);
                        continue;
                    }
                    let shared = Arc::clone(&self.shared);
                    conns.push(std::thread::spawn(move || {
                        shared.active_conns.fetch_add(1, Ordering::SeqCst);
                        handle_connection(stream, &shared);
                        shared.active_conns.fetch_sub(1, Ordering::SeqCst);
                    }));
                }
                Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(ACCEPT_POLL_INTERVAL);
                }
                Err(_) => break,
            }
            conns.retain(|h| !h.is_finished());
        }
        // Graceful drain: every connection thread notices the stop flag
        // within one read-timeout interval, finishes writing any in-flight
        // response first, and returns.
        for h in conns {
            let _ = h.join();
        }
        // Durable shutdown: with every connection drained, snapshot all
        // live models and hit the fsync barrier, so a graceful stop never
        // leaves replay debt behind. Best-effort — a full disk must not
        // turn a clean shutdown into a hang or a panic.
        if self.shared.registry.store().is_some() {
            if let Err(e) = self.shared.registry.persist_all(None) {
                eprintln!("warning: shutdown snapshot failed: {e}");
            }
        }
    }
}

/// Best-effort overload response on a connection we refuse to serve. The
/// write gets a short timeout so a non-reading client cannot stall the
/// accept loop.
fn shed(mut stream: TcpStream) {
    let line = protocol::err_with(
        "overloaded",
        vec![("retry_after_s", Json::from(RETRY_AFTER_S))],
    );
    let _ = stream.set_write_timeout(Some(Duration::from_millis(200)));
    let _ = stream.write_all(line.as_bytes());
    let _ = stream.write_all(b"\n");
}

fn handle_connection(stream: TcpStream, shared: &Arc<Shared>) {
    // Short read timeout so the thread re-checks the stop flag instead of
    // blocking forever on an idle client (run() joins these threads at
    // shutdown; an indefinite blocking read would deadlock the server).
    let _ = stream.set_read_timeout(Some(Duration::from_millis(200)));
    let writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    // Responses from the read loop and from pipelined workers interleave
    // on one socket; the mutex makes each *line* atomic (a worker writes
    // its whole tagged response or nothing between two other lines).
    let writer = Arc::new(Mutex::new(writer));
    let mut pipeline: Vec<std::thread::JoinHandle<()>> = Vec::new();
    serve_lines(BufReader::new(stream), shared, &writer, &mut pipeline);
    // Graceful drain, pipelined edition: whatever made the read loop
    // return (client close, stop flag, fatal line), every in-flight
    // tagged request still finishes and writes its tagged response
    // before the connection thread retires — run() joins *this* thread,
    // so the shutdown drain contract covers workers transitively.
    for h in pipeline {
        let _ = h.join();
    }
}

/// Write one response line (serialized against concurrent workers on the
/// same connection). Returns `false` once the socket is unusable.
fn write_line(writer: &Mutex<TcpStream>, line: &str) -> bool {
    let mut w = writer.lock().unwrap_or_else(|e| e.into_inner());
    w.write_all(line.as_bytes()).is_ok()
        && w.write_all(b"\n").is_ok()
        && w.flush().is_ok()
}

/// The per-connection read loop. Untagged requests keep the classic
/// synchronous contract (decode → respond → write, in order); tagged
/// requests are dispatched to short-lived worker threads so many can be
/// in flight at once, their responses written in completion order with
/// the id spliced back in (see `PROTOCOL.md` §Concurrency). In-flight
/// workers are capped by [`ServerConfig::max_pipeline`]; beyond it the
/// request is shed immediately with a tagged `pipeline full` error.
fn serve_lines(
    mut reader: BufReader<TcpStream>,
    shared: &Arc<Shared>,
    writer: &Arc<Mutex<TcpStream>>,
    pipeline: &mut Vec<std::thread::JoinHandle<()>>,
) {
    let cap = shared.config.max_line_bytes;
    let mut buf: Vec<u8> = Vec::new();
    loop {
        if shared.stop.load(Ordering::SeqCst) {
            return;
        }
        if !buf.ends_with(b"\n") {
            // Read up to the cap (+1 so overflow is detectable), keeping
            // any partial line across timeouts. A slow client that
            // trickles bytes makes progress; one that streams an unbounded
            // line hits the cap instead of exhausting memory.
            let room = (cap + 1 - buf.len()) as u64;
            match (&mut reader).take(room).read_until(b'\n', &mut buf) {
                Ok(0) => return, // client closed (possibly mid-line)
                Ok(_) => {}
                Err(ref e)
                    if e.kind() == std::io::ErrorKind::WouldBlock
                        || e.kind() == std::io::ErrorKind::TimedOut =>
                {
                    // Timeout leaves any partial line buffered; retry.
                    continue;
                }
                Err(_) => return,
            }
            if !buf.ends_with(b"\n") {
                if buf.len() > cap {
                    let resp = protocol::err(&format!(
                        "request too large: line exceeds {cap} bytes"
                    ));
                    let _ = write_line(writer, &resp);
                    return;
                }
                continue; // partial line: wait for the rest
            }
        }
        let request = String::from_utf8_lossy(&buf).into_owned();
        buf.clear();
        if request.trim().is_empty() {
            continue;
        }
        match protocol::decode_tagged(&request) {
            // A line that does not decode cannot be correlated reliably
            // (its id, if any, may itself be the malformed part), so the
            // error goes back untagged and in order.
            Err(e) => {
                if !write_line(writer, &protocol::err(&e)) {
                    return;
                }
            }
            Ok((None, req)) => {
                let response = respond(req, shared);
                if !write_line(writer, &response) {
                    return;
                }
            }
            Ok((Some(id), req)) => {
                pipeline.retain(|h| !h.is_finished());
                if pipeline.len() >= shared.config.max_pipeline {
                    // Admission control below the connection cap: shed
                    // *this request* (tagged, so the client knows which
                    // one) instead of queueing unboundedly or blocking
                    // the whole connection behind slow solves.
                    let resp = protocol::tag_response(
                        id,
                        &protocol::err_with(
                            "pipeline full",
                            vec![
                                ("retry_after_s", Json::from(RETRY_AFTER_S)),
                                (
                                    "max_pipeline",
                                    Json::from(shared.config.max_pipeline),
                                ),
                            ],
                        ),
                    );
                    if !write_line(writer, &resp) {
                        return;
                    }
                    continue;
                }
                let shared = Arc::clone(shared);
                let writer = Arc::clone(writer);
                pipeline.push(std::thread::spawn(move || {
                    let response = protocol::tag_response(id, &respond(req, &shared));
                    let _ = write_line(&writer, &response);
                }));
            }
        }
    }
}

/// Scheduler-style panic isolation for the synchronous registry path: a
/// panicking solve (e.g. a factorization failing on pathological but
/// wire-valid data) must produce a clean `{"ok":false}` — not a dead
/// connection. Catching *inside* the session-lock scope also keeps the
/// mutex unpoisoned (the unwind never crosses the guard), so the model
/// stays usable afterwards.
fn catch_panic<R>(f: impl FnOnce() -> Result<R, String>) -> Result<R, String> {
    match std::panic::catch_unwind(std::panic::AssertUnwindSafe(f)) {
        Ok(r) => r,
        Err(panic) => Err(super::scheduler::panic_message(&*panic)),
    }
}

/// Effective wall deadline for one registry request: the wire-level
/// `"deadline_s"` wins; otherwise the server-wide `--request-timeout-s`
/// default applies (if configured).
fn wall_deadline(shared: &Shared, deadline_s: Option<f64>) -> Option<Instant> {
    deadline_s
        .map(Duration::from_secs_f64)
        .or(shared.config.request_timeout)
        .map(|d| Instant::now() + d)
}

fn respond(req: Request, shared: &Shared) -> String {
    let scheduler = &shared.scheduler;
    let registry = &shared.registry;
    match req {
        Request::Ping => protocol::ok(vec![("pong", Json::Bool(true))]),
        Request::Health => {
            let draining = shared.stop.load(Ordering::SeqCst);
            let mut fields = vec![
                ("status", Json::from(if draining { "draining" } else { "ok" })),
                ("backlog", Json::from(scheduler.backlog())),
                ("models", Json::from(registry.len())),
                ("model_bytes", Json::from(registry.total_bytes())),
                ("connections", Json::from(shared.active_conns.load(Ordering::SeqCst))),
                ("workers", Json::from(shared.config.workers)),
            ];
            if let Some(store) = registry.store() {
                fields.extend([
                    ("durability", Json::from(store.policy().to_string())),
                    ("dirty_models", Json::from(registry.dirty_models())),
                    ("wal_lag_bytes", Json::from(store.wal_lag_bytes())),
                ]);
                if let Some(age) = store.last_snapshot_age_s() {
                    fields.push(("last_snapshot_age_s", Json::from(age)));
                }
            }
            protocol::ok(fields)
        }
        Request::Metrics => protocol::ok(vec![
            ("metrics", scheduler.metrics().to_json()),
            ("backlog", Json::from(scheduler.backlog())),
            ("registry", registry.stats_json()),
        ]),
        Request::Register { workload, kind, seed, name } => {
            let name = name.unwrap_or_else(|| match &workload {
                super::job::Workload::Synthetic { profile, n, d, .. } => {
                    format!("{profile}-{n}x{d}")
                }
                super::job::Workload::Inline { a, .. } => {
                    format!("inline-{}x{}", a.rows(), a.cols())
                }
            });
            // materialize() can panic on shapes the generators assert on
            // (e.g. non-power-of-two synthetic dims) — isolate like the
            // scheduler's workers do.
            match catch_panic(|| {
                workload.materialize().and_then(|(a, b)| registry.register(name, a, b, kind, seed))
            }) {
                Ok(entry) => {
                    let s = entry.session.lock().unwrap();
                    protocol::ok(vec![
                        ("model", Json::from(entry.id)),
                        ("name", Json::from(entry.name.clone())),
                        ("n", Json::from(s.n())),
                        ("d", Json::from(s.d())),
                        ("sketch", Json::from(s.kind().to_string())),
                        ("bytes", Json::from(s.approx_bytes())),
                    ])
                }
                Err(e) => protocol::err(&e),
            }
        }
        Request::Query { model, nu, nus, eps, include_x, b, bs, deadline_s } => {
            let Some(entry) = registry.touch(model) else {
                return protocol::err(&Registry::unknown(model));
            };
            // Lock-free fast path: a plain repeat-`nu` query whose exact
            // `(nu, eps)` is cached in the published snapshot is answered
            // without ever acquiring the session mutex — concurrent
            // repeats of a hot operating point overlap freely with each
            // other *and* with a writer mutating the session under its
            // lock. The snapshot is immutable, so the answer is bitwise
            // the one its generation committed. Everything the snapshot
            // cannot answer read-only (uncached points, paths, alternate
            // RHS, batches) falls through to the locked writer path.
            if b.is_none() && bs.is_none() && nus.is_empty() {
                let snap = entry.snapshot();
                if let Some(sol) = snap.cached(nu, eps) {
                    registry.note_snapshot_query(&entry);
                    return protocol::ok(vec![
                        ("model", Json::from(model)),
                        ("result", solution_json(nu, &sol, include_x)),
                        ("m", Json::from(snap.m())),
                    ]);
                }
                // Frozen read lane: an *uncached* single-`nu` query runs
                // the full adaptive iteration against the snapshot's
                // pinned panel + view — still no session mutex, so
                // distinct-`nu` readers of one hot model overlap freely
                // with each other and with a writer. The answer is
                // bitwise the one the writer lane would produce from this
                // generation; nothing is cached and the warm start does
                // not advance (the writer lane owns all mutation).
                // `None` (no solver state yet / pending lazy appends) and
                // `NeedsGrowth` (frozen `m` too small for this `nu`, or a
                // recovery condition) fall back to the mutex lane below,
                // which owns growth and the recovery ladder.
                match snap.solve_frozen(nu, eps, wall_deadline(shared, deadline_s)) {
                    Some(Ok(FrozenOutcome::Solved(sol))) => {
                        registry.note_frozen_solve(&entry);
                        return protocol::ok(vec![
                            ("model", Json::from(model)),
                            ("result", solution_json(nu, &sol, include_x)),
                            ("m", Json::from(snap.m())),
                        ]);
                    }
                    Some(Ok(FrozenOutcome::NeedsGrowth { .. })) => {
                        registry.note_frozen_fallback(&entry);
                    }
                    // Definitive input/deadline error — the writer path
                    // would fail the same way; don't duplicate the work
                    // just to fail again. Failed work is still a served
                    // query (the mutex lane counts its failures too).
                    Some(Err(e)) => {
                        registry.queries.fetch_add(1, Ordering::Relaxed);
                        entry.snap_queries.fetch_add(1, Ordering::Relaxed);
                        return protocol::err(&e);
                    }
                    None => {}
                }
            }
            let mut session = entry.session.lock().unwrap();
            session.set_deadline(wall_deadline(shared, deadline_s));
            let outcome = if let Some(bs) = bs {
                // Block multi-RHS: all columns through one BLAS-3
                // iteration against the session's cached sketch; one
                // result object per input column, in order.
                catch_panic(|| session.solve_block(nu, &bs, eps)).map(|sols| {
                    let entries =
                        sols.iter().map(|sol| solution_json(nu, sol, include_x)).collect();
                    vec![("batch", Json::Arr(entries))]
                })
            } else if let Some(b) = b {
                catch_panic(|| session.solve_rhs(nu, &b, eps)).map(|sol| {
                    vec![("result", solution_json(nu, &sol, include_x))]
                })
            } else if !nus.is_empty() {
                catch_panic(|| session.solve_path(&nus, eps)).map(|sols| {
                    let points = nus
                        .iter()
                        .zip(&sols)
                        .map(|(&nu, sol)| solution_json(nu, sol, include_x))
                        .collect();
                    vec![("path", Json::Arr(points))]
                })
            } else {
                catch_panic(|| session.solve(nu, eps)).map(|sol| {
                    vec![("result", solution_json(nu, &sol, include_x))]
                })
            };
            session.set_deadline(None);
            // Byte accounting must see partial growth too: a path query
            // that errors halfway (e.g. an unsorted nu) may already have
            // grown the cached sketch on its solved points.
            registry.note_query(&entry, &session);
            // Publish only on success: a failed call rolled the session
            // back to exactly the state already published, so skipping
            // the swap is what keeps "failed writers never publish"
            // airtight (and a path that committed early points publishes
            // them with its next successful query).
            if outcome.is_ok() {
                if let Err(e) = entry.publish(&mut session) {
                    eprintln!("warning: snapshot publish for model {model} skipped: {e}");
                }
            }
            match outcome {
                Ok(mut fields) => {
                    fields.insert(0, ("model", Json::from(model)));
                    fields.push(("m", Json::from(session.m())));
                    protocol::ok(fields)
                }
                Err(e) => protocol::err(&e),
            }
        }
        Request::Predict { model, nu, rows, eps, deadline_s } => {
            let Some(entry) = registry.touch(model) else {
                return protocol::err(&Registry::unknown(model));
            };
            // Lock-free fast path: predictions over an already-cached
            // `(nu, eps)` solution are pure dot products against an
            // immutable snapshot — no session mutex. A `Some(Err)` here
            // is a definitive row-validation error (identical to what
            // the writer path would produce); only an uncached solution
            // falls through to the locked solve-then-predict path.
            if let Some(res) = entry.snapshot().predict_cached(nu, &rows, eps) {
                registry.note_snapshot_query(&entry);
                return match res {
                    Ok(y) => protocol::ok(vec![
                        ("model", Json::from(model)),
                        ("nu", Json::from(nu)),
                        ("y", Json::Arr(y.into_iter().map(Json::from).collect())),
                    ]),
                    Err(e) => protocol::err(&e),
                };
            }
            let mut session = entry.session.lock().unwrap();
            session.set_deadline(wall_deadline(shared, deadline_s));
            let outcome = catch_panic(|| session.predict(nu, &rows, eps));
            session.set_deadline(None);
            registry.note_query(&entry, &session);
            if outcome.is_ok() {
                if let Err(e) = entry.publish(&mut session) {
                    eprintln!("warning: snapshot publish for model {model} skipped: {e}");
                }
            }
            match outcome {
                Ok(y) => protocol::ok(vec![
                    ("model", Json::from(model)),
                    ("nu", Json::from(nu)),
                    ("y", Json::Arr(y.into_iter().map(Json::from).collect())),
                ]),
                Err(e) => protocol::err(&e),
            }
        }
        Request::Append { model, a, b, eager, deadline_s } => {
            let Some(entry) = registry.touch(model) else {
                return protocol::err(&Registry::unknown(model));
            };
            let refresh = if eager {
                crate::solvers::session::AppendRefresh::Eager
            } else {
                crate::solvers::session::AppendRefresh::Lazy
            };
            let mut session = entry.session.lock().unwrap();
            // Write-ahead: the delta is logged durably *before* it is
            // applied, so an ack implies the rows survive a crash. A WAL
            // write failure rejects the append outright (nothing was
            // applied); a session rejection rolls the record back (it
            // must not replay on recovery). The log happens under the
            // session lock so record order matches apply order.
            let wal_offset = match registry.store() {
                None => None,
                Some(store) => match store.append_record(model, &a, &b, eager) {
                    Ok(off) => Some(off),
                    Err(e) => {
                        registry.note_append(&entry, &session);
                        return protocol::err(&format!("append not logged: {e}"));
                    }
                },
            };
            session.set_deadline(wall_deadline(shared, deadline_s));
            let outcome = catch_panic(|| session.append(a, b, refresh));
            session.set_deadline(None);
            if outcome.is_err() {
                if let (Some(store), Some(off)) = (registry.store(), wal_offset) {
                    if let Err(e) = store.rollback_append(model, off) {
                        eprintln!("warning: WAL rollback for model {model} failed: {e}");
                    }
                }
            }
            // Recharge the byte accounting even on error: the session
            // rolls itself back, but the registry's cached size must track
            // whatever state survived.
            registry.note_append(&entry, &session);
            // WAL-before-apply meets snapshot publication: the record was
            // durable before the apply, the apply committed under the
            // session lock, and only then does the new generation become
            // visible to lock-free readers — a crash at any point leaves
            // either the old snapshot live (rows still replayable from
            // the WAL) or the new one fully applied, never a torn view.
            if outcome.is_ok() {
                if let Err(e) = entry.publish(&mut session) {
                    eprintln!("warning: snapshot publish for model {model} skipped: {e}");
                }
            }
            match outcome {
                Ok(out) => protocol::ok(vec![
                    ("model", Json::from(model)),
                    ("rows_added", Json::from(out.rows_added)),
                    ("n", Json::from(out.n)),
                    ("m", Json::from(out.m)),
                    ("refreshed", Json::Bool(out.refreshed)),
                    ("bytes", Json::from(session.approx_bytes())),
                ]),
                Err(e) => protocol::err(&e),
            }
        }
        Request::Evict { model, purge } => {
            if registry.evict(model, purge) {
                protocol::ok(vec![
                    ("evicted", Json::from(model)),
                    ("purged", Json::Bool(purge && registry.store().is_some())),
                ])
            } else {
                protocol::err(&Registry::unknown(model))
            }
        }
        Request::Snapshot { model } => match registry.persist_all(model) {
            Ok(persisted) => protocol::ok(vec![
                ("snapshotted", Json::from(persisted)),
                ("wal_lag_bytes", Json::from(
                    registry.store().map_or(0, |s| s.wal_lag_bytes()),
                )),
            ]),
            Err(e) => protocol::err(&e),
        },
        Request::Models => protocol::ok(vec![("models", registry.models_json())]),
        Request::Solvers => {
            let entries = crate::solvers::api::registry()
                .into_iter()
                .map(|spec| {
                    Json::obj(vec![
                        ("spec", Json::from(spec.to_string())),
                        ("description", Json::from(spec.describe())),
                    ])
                })
                .collect();
            protocol::ok(vec![("solvers", Json::Arr(entries))])
        }
        Request::Shutdown => {
            shared.stop.store(true, Ordering::SeqCst);
            protocol::ok(vec![("stopping", Json::Bool(true))])
        }
        // Job ids are u64: encode them as such — `id as usize` would
        // truncate above 2^32 on 32-bit targets.
        Request::Solve(spec) => match scheduler.submit(spec) {
            Ok(id) => protocol::ok(vec![("job", Json::from(id))]),
            Err(e) => protocol::err(&e.to_string()),
        },
        Request::Status { job } => match scheduler.status(job) {
            None => protocol::err("unknown job"),
            Some(state) => protocol::ok(vec![("state", Json::from(state.label()))]),
        },
        Request::Wait { job, timeout_s } => {
            match scheduler.wait(job, Duration::from_secs_f64(timeout_s.max(0.0))) {
                None => protocol::err("unknown job"),
                Some(state) => state_response(state, false),
            }
        }
        Request::Result { job, include_x } => match scheduler.status(job) {
            None => protocol::err("unknown job"),
            Some(state) => state_response(state, include_x),
        },
    }
}

/// One query result: `nu` + the usual report fields (+ `x` on request).
/// Shares the job-outcome field encoding so `solve` and `query`
/// responses stay field-compatible, without cloning the solution.
fn solution_json(nu: f64, sol: &crate::solvers::Solution, include_x: bool) -> Json {
    let mut fields = super::job::report_fields(&sol.report);
    fields.push(("nu", Json::from(nu)));
    if include_x {
        fields.push(("x", Json::Arr(sol.x.iter().map(|&v| Json::from(v)).collect())));
    }
    Json::obj(fields)
}

fn state_response(state: JobState, include_x: bool) -> String {
    match state {
        JobState::Done(outcome) => protocol::ok(vec![
            ("state", Json::from("done")),
            ("result", outcome.to_json(include_x)),
        ]),
        JobState::Failed(msg) => protocol::ok(vec![
            ("state", Json::from("failed")),
            ("error", Json::from(msg)),
        ]),
        other => protocol::ok(vec![("state", Json::from(other.label()))]),
    }
}

/// Minimal blocking client for tests, examples, and the CLI.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    max_line_bytes: usize,
}

impl Client {
    /// Open a connection to a running coordinator.
    pub fn connect(addr: std::net::SocketAddr) -> std::io::Result<Self> {
        let stream = TcpStream::connect(addr)?;
        let writer = stream.try_clone()?;
        Ok(Self {
            reader: BufReader::new(stream),
            writer,
            max_line_bytes: DEFAULT_MAX_LINE_BYTES,
        })
    }

    /// Cap on a single response line (default
    /// [`DEFAULT_MAX_LINE_BYTES`]); a longer response errors instead of
    /// buffering without bound.
    pub fn set_line_cap(&mut self, bytes: usize) {
        self.max_line_bytes = bytes;
    }

    /// Send one request line, read one response line, parse it.
    pub fn call(&mut self, request: &str) -> Result<Json, String> {
        self.send(request)?;
        self.recv()
    }

    /// Send one request line without waiting for the response — the
    /// pipelining half-call. Pair with [`Client::recv`]; tag requests
    /// with `"id"` so possibly-reordered responses can be correlated
    /// (see `PROTOCOL.md` §Concurrency).
    pub fn send(&mut self, request: &str) -> Result<(), String> {
        self.writer
            .write_all(request.as_bytes())
            .and_then(|_| self.writer.write_all(b"\n"))
            .and_then(|_| self.writer.flush())
            .map_err(|e| e.to_string())
    }

    /// Read and parse the next response line, whichever in-flight
    /// request it answers (tagged responses carry their request's `"id"`
    /// as the first field).
    pub fn recv(&mut self) -> Result<Json, String> {
        let mut buf: Vec<u8> = Vec::new();
        let cap = self.max_line_bytes;
        let n = (&mut self.reader)
            .take(cap as u64 + 1)
            .read_until(b'\n', &mut buf)
            .map_err(|e| e.to_string())?;
        if n == 0 {
            return Err("connection closed by server".into());
        }
        if !buf.ends_with(b"\n") && buf.len() > cap {
            return Err(format!("response too large: line exceeds {cap} bytes"));
        }
        let line = String::from_utf8_lossy(&buf);
        crate::util::json::parse(line.trim()).map_err(|e| e.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn start_server() -> (std::net::SocketAddr, Arc<AtomicBool>, std::thread::JoinHandle<()>) {
        start_with_config(ServerConfig::default())
    }

    fn start_with_config(
        config: ServerConfig,
    ) -> (std::net::SocketAddr, Arc<AtomicBool>, std::thread::JoinHandle<()>) {
        let server = Server::bind_with_config("127.0.0.1:0", config).unwrap();
        let addr = server.local_addr();
        let stop = server.stop_handle();
        let handle = std::thread::spawn(move || server.run());
        (addr, stop, handle)
    }

    #[test]
    fn ping_and_metrics() {
        let (addr, stop, handle) = start_server();
        let mut client = Client::connect(addr).unwrap();
        let pong = client.call(r#"{"cmd":"ping"}"#).unwrap();
        assert_eq!(pong.get("ok").unwrap().as_bool(), Some(true));
        let metrics = client.call(r#"{"cmd":"metrics"}"#).unwrap();
        assert!(metrics.get("metrics").is_some());
        stop.store(true, Ordering::SeqCst);
        handle.join().unwrap();
    }

    #[test]
    fn health_reports_load() {
        let (addr, stop, handle) = start_server();
        let mut client = Client::connect(addr).unwrap();
        let h = client.call(r#"{"cmd":"health"}"#).unwrap();
        assert_eq!(h.get("ok").unwrap().as_bool(), Some(true), "{h:?}");
        assert_eq!(h.get("status").unwrap().as_str(), Some("ok"));
        assert_eq!(h.get("models").unwrap().as_usize(), Some(0));
        assert!(h.get("connections").unwrap().as_usize().unwrap() >= 1);
        assert!(h.get("backlog").is_some());
        stop.store(true, Ordering::SeqCst);
        handle.join().unwrap();
    }

    #[test]
    fn oversize_request_answers_structured_error() {
        let (addr, stop, handle) =
            start_with_config(ServerConfig { max_line_bytes: 1024, ..ServerConfig::default() });
        let mut client = Client::connect(addr).unwrap();
        let big = format!(r#"{{"cmd":"ping","pad":"{}"}}"#, "x".repeat(4096));
        let resp = client.call(&big).unwrap();
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false), "{resp:?}");
        assert!(resp.get("error").unwrap().as_str().unwrap().contains("request too large"));
        // The oversize connection is closed, but the server keeps serving
        // fresh connections normally.
        let mut c2 = Client::connect(addr).unwrap();
        let pong = c2.call(r#"{"cmd":"ping"}"#).unwrap();
        assert_eq!(pong.get("ok").unwrap().as_bool(), Some(true));
        stop.store(true, Ordering::SeqCst);
        handle.join().unwrap();
    }

    #[test]
    fn overload_sheds_with_retry_hint() {
        let (addr, stop, handle) =
            start_with_config(ServerConfig { max_conns: 1, ..ServerConfig::default() });
        let mut c1 = Client::connect(addr).unwrap();
        assert_eq!(c1.call(r#"{"cmd":"ping"}"#).unwrap().get("ok").unwrap().as_bool(), Some(true));
        // Second concurrent connection: shed with a structured hint.
        let mut c2 = Client::connect(addr).unwrap();
        let resp = c2.call(r#"{"cmd":"ping"}"#).unwrap();
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false), "{resp:?}");
        assert_eq!(resp.get("error").unwrap().as_str(), Some("overloaded"));
        assert!(resp.get("retry_after_s").unwrap().as_f64().unwrap() > 0.0);
        // Once the first client departs, the slot frees up again.
        drop(c1);
        let deadline = Instant::now() + Duration::from_secs(30);
        loop {
            let mut c3 = Client::connect(addr).unwrap();
            match c3.call(r#"{"cmd":"ping"}"#) {
                Ok(v) if v.get("ok").and_then(Json::as_bool) == Some(true) => break,
                _ => {}
            }
            assert!(Instant::now() < deadline, "shed slot never freed");
            std::thread::sleep(Duration::from_millis(20));
        }
        stop.store(true, Ordering::SeqCst);
        handle.join().unwrap();
    }

    #[test]
    fn tagged_requests_pipeline_on_one_connection() {
        let (addr, stop, handle) = start_server();
        let mut client = Client::connect(addr).unwrap();
        // Fire three tagged pings without waiting for any response, then
        // collect all three.  Completion order is unspecified, so match by id.
        for id in [7u64, 8, 9] {
            client.send(&format!(r#"{{"id":{id},"cmd":"ping"}}"#)).unwrap();
        }
        let mut seen = Vec::new();
        for _ in 0..3 {
            let resp = client.recv().unwrap();
            assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true), "{resp:?}");
            seen.push(resp.get("id").unwrap().as_usize().unwrap());
        }
        seen.sort_unstable();
        assert_eq!(seen, vec![7, 8, 9]);
        // The connection is still usable for plain untagged calls afterwards.
        let pong = client.call(r#"{"cmd":"ping"}"#).unwrap();
        assert_eq!(pong.get("ok").unwrap().as_bool(), Some(true));
        assert!(pong.get("id").is_none(), "untagged request must get an untagged response");
        stop.store(true, Ordering::SeqCst);
        handle.join().unwrap();
    }

    #[test]
    fn pipeline_admission_sheds_tagged_requests_with_a_tagged_error() {
        // max_pipeline == 0 makes every tagged request exceed the in-flight
        // cap, so shedding is deterministic.
        let (addr, stop, handle) =
            start_with_config(ServerConfig { max_pipeline: 0, ..ServerConfig::default() });
        let mut client = Client::connect(addr).unwrap();
        client.send(r#"{"id":42,"cmd":"ping"}"#).unwrap();
        let resp = client.recv().unwrap();
        assert_eq!(resp.get("id").unwrap().as_usize(), Some(42), "{resp:?}");
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false));
        assert_eq!(resp.get("error").unwrap().as_str(), Some("pipeline full"));
        assert!(resp.get("retry_after_s").unwrap().as_f64().unwrap() > 0.0);
        assert_eq!(resp.get("max_pipeline").unwrap().as_usize(), Some(0));
        // Untagged requests bypass the pipeline and still work.
        let pong = client.call(r#"{"cmd":"ping"}"#).unwrap();
        assert_eq!(pong.get("ok").unwrap().as_bool(), Some(true));
        stop.store(true, Ordering::SeqCst);
        handle.join().unwrap();
    }

    #[test]
    fn expired_deadline_answers_clean_error_and_model_survives() {
        let (addr, stop, handle) = start_server();
        let mut client = Client::connect(addr).unwrap();
        let reg = client
            .call(r#"{"cmd":"register","profile":"exp","n":128,"d":16,"seed":6,"name":"dl"}"#)
            .unwrap();
        assert_eq!(reg.get("ok").unwrap().as_bool(), Some(true), "{reg:?}");
        let model = reg.get("model").unwrap().as_usize().unwrap();
        let late = client
            .call(&format!(r#"{{"cmd":"query","model":{model},"nu":0.5,"deadline_s":1e-9}}"#))
            .unwrap();
        assert_eq!(late.get("ok").unwrap().as_bool(), Some(false), "{late:?}");
        assert!(late.get("error").unwrap().as_str().unwrap().contains("deadline"));
        // The rollback leaves the model fully usable.
        let q = client.call(&format!(r#"{{"cmd":"query","model":{model},"nu":0.5}}"#)).unwrap();
        assert_eq!(q.get("ok").unwrap().as_bool(), Some(true), "{q:?}");
        stop.store(true, Ordering::SeqCst);
        handle.join().unwrap();
    }

    #[test]
    fn invalid_nu_eps_answer_structured_errors_over_tcp() {
        let (addr, stop, handle) = start_server();
        let mut client = Client::connect(addr).unwrap();
        for (line, prefix) in [
            (r#"{"cmd":"query","model":1,"nu":-1.0}"#, "invalid nu"),
            (r#"{"cmd":"query","model":1,"nu":0}"#, "invalid nu"),
            (r#"{"cmd":"query","model":1,"eps":0}"#, "invalid eps"),
            (r#"{"cmd":"solve","nu":1e999}"#, "invalid nu"),
            (r#"{"cmd":"query","model":1,"deadline_s":-1}"#, "invalid deadline_s"),
        ] {
            let resp = client.call(line).unwrap();
            assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false), "{line}");
            let err = resp.get("error").unwrap().as_str().unwrap();
            assert!(err.starts_with(prefix), "{line}: {err}");
        }
        stop.store(true, Ordering::SeqCst);
        handle.join().unwrap();
    }

    #[test]
    fn solve_roundtrip_over_tcp() {
        let (addr, stop, handle) = start_server();
        let mut client = Client::connect(addr).unwrap();
        let resp = client
            .call(r#"{"cmd":"solve","profile":"exp","n":128,"d":16,"nu":0.5,"solver":"adaptive","eps":1e-8,"seed":3}"#)
            .unwrap();
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true));
        let job = resp.get("job").unwrap().as_usize().unwrap();
        let done = client
            .call(&format!(r#"{{"cmd":"wait","job":{job},"timeout_s":60}}"#))
            .unwrap();
        assert_eq!(done.get("state").unwrap().as_str(), Some("done"));
        let result = done.get("result").unwrap();
        assert_eq!(result.get("converged").unwrap().as_bool(), Some(true));
        assert_eq!(result.get("recovery").unwrap().as_str(), Some("none"));
        stop.store(true, Ordering::SeqCst);
        handle.join().unwrap();
    }

    #[test]
    fn solvers_command_lists_registry() {
        let (addr, stop, handle) = start_server();
        let mut client = Client::connect(addr).unwrap();
        let resp = client.call(r#"{"cmd":"solvers"}"#).unwrap();
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true));
        let listed = resp.get("solvers").unwrap().as_arr().unwrap();
        let registry = crate::solvers::api::registry();
        assert_eq!(listed.len(), registry.len());
        for (entry, spec) in listed.iter().zip(&registry) {
            assert_eq!(entry.get("spec").unwrap().as_str(), Some(spec.to_string().as_str()));
        }
        stop.store(true, Ordering::SeqCst);
        handle.join().unwrap();
    }

    #[test]
    fn register_query_predict_evict_over_tcp() {
        let (addr, stop, handle) = start_server();
        let mut client = Client::connect(addr).unwrap();
        let reg = client
            .call(r#"{"cmd":"register","profile":"exp","n":128,"d":16,"seed":3,"name":"t"}"#)
            .unwrap();
        assert_eq!(reg.get("ok").unwrap().as_bool(), Some(true), "{reg:?}");
        let model = reg.get("model").unwrap().as_usize().unwrap();
        assert_eq!(reg.get("n").unwrap().as_usize(), Some(128));

        let q = client
            .call(&format!(r#"{{"cmd":"query","model":{model},"nu":0.5,"include_x":true}}"#))
            .unwrap();
        assert_eq!(q.get("ok").unwrap().as_bool(), Some(true), "{q:?}");
        let result = q.get("result").unwrap();
        assert_eq!(result.get("converged").unwrap().as_bool(), Some(true));
        assert_eq!(result.get("x").unwrap().as_arr().unwrap().len(), 16);

        let p = client
            .call(&format!(
                r#"{{"cmd":"predict","model":{model},"nu":0.5,"rows":[{:?}]}}"#,
                vec![0.5f64; 16]
            ))
            .unwrap();
        assert_eq!(p.get("ok").unwrap().as_bool(), Some(true), "{p:?}");
        assert_eq!(p.get("y").unwrap().as_arr().unwrap().len(), 1);

        let listing = client.call(r#"{"cmd":"models"}"#).unwrap();
        assert_eq!(listing.get("models").unwrap().as_arr().unwrap().len(), 1);

        let ev = client.call(&format!(r#"{{"cmd":"evict","model":{model}}}"#)).unwrap();
        assert_eq!(ev.get("ok").unwrap().as_bool(), Some(true));
        let gone = client.call(&format!(r#"{{"cmd":"query","model":{model},"nu":1.0}}"#)).unwrap();
        assert_eq!(gone.get("ok").unwrap().as_bool(), Some(false));
        assert!(gone.get("error").unwrap().as_str().unwrap().contains("unknown model"));

        let metrics = client.call(r#"{"cmd":"metrics"}"#).unwrap();
        let reg_stats = metrics.get("registry").unwrap();
        assert_eq!(reg_stats.get("registered").unwrap().as_usize(), Some(1));
        assert_eq!(reg_stats.get("evicted").unwrap().as_usize(), Some(1));

        stop.store(true, Ordering::SeqCst);
        handle.join().unwrap();
    }

    #[test]
    fn uncached_nu_queries_take_the_frozen_lane_over_tcp() {
        let (addr, stop, handle) = start_server();
        let mut client = Client::connect(addr).unwrap();
        let reg = client
            .call(r#"{"cmd":"register","profile":"exp","n":512,"d":64,"seed":6,"name":"fz"}"#)
            .unwrap();
        assert_eq!(reg.get("ok").unwrap().as_bool(), Some(true), "{reg:?}");
        let model = reg.get("model").unwrap().as_usize().unwrap();

        // Writer lane: the first solve warms the model and publishes the
        // snapshot the frozen lane will serve from. A large nu keeps the
        // published sketch small, so a later small-nu query must defer.
        let warm = client
            .call(&format!(r#"{{"cmd":"query","model":{model},"nu":50.0}}"#))
            .unwrap();
        assert_eq!(warm.get("ok").unwrap().as_bool(), Some(true), "{warm:?}");

        // Uncached, easier nu (larger => smaller effective dimension):
        // answered by the frozen lane from the pinned snapshot artifacts.
        let q = client
            .call(&format!(r#"{{"cmd":"query","model":{model},"nu":80.0,"include_x":true}}"#))
            .unwrap();
        assert_eq!(q.get("ok").unwrap().as_bool(), Some(true), "{q:?}");
        assert_eq!(q.get("result").unwrap().get("converged").unwrap().as_bool(), Some(true));
        let reg_stats = |client: &mut Client| {
            client.call(r#"{"cmd":"metrics"}"#).unwrap().get("registry").unwrap().clone()
        };
        let stats = reg_stats(&mut client);
        assert_eq!(stats.get("frozen_solves").unwrap().as_usize(), Some(1), "{stats:?}");
        assert_eq!(stats.get("frozen_fallbacks").unwrap().as_usize(), Some(0));

        // A hard nu the frozen m cannot cover: NeedsGrowth falls the
        // query back to the writer lane, which grows, answers, and
        // republishes — one fallback, one (writer-counted) query.
        let hard = client
            .call(&format!(r#"{{"cmd":"query","model":{model},"nu":0.05}}"#))
            .unwrap();
        assert_eq!(hard.get("ok").unwrap().as_bool(), Some(true), "{hard:?}");
        let stats = reg_stats(&mut client);
        assert_eq!(stats.get("frozen_fallbacks").unwrap().as_usize(), Some(1), "{stats:?}");
        assert_eq!(stats.get("frozen_solves").unwrap().as_usize(), Some(1));

        // After the republish the grown panel covers nearby nus: an
        // uncached query in that range is frozen again.
        let q2 = client
            .call(&format!(r#"{{"cmd":"query","model":{model},"nu":0.07}}"#))
            .unwrap();
        assert_eq!(q2.get("ok").unwrap().as_bool(), Some(true), "{q2:?}");
        let stats = reg_stats(&mut client);
        assert_eq!(stats.get("frozen_solves").unwrap().as_usize(), Some(2), "{stats:?}");

        // The per-model listing surfaces the same counters lock-free.
        let listing = client.call(r#"{"cmd":"models"}"#).unwrap();
        let m0 = &listing.get("models").unwrap().as_arr().unwrap()[0];
        assert_eq!(m0.get("frozen_solves").unwrap().as_usize(), Some(2), "{m0:?}");
        assert_eq!(m0.get("frozen_fallbacks").unwrap().as_usize(), Some(1));
        assert!(m0.get("generation").unwrap().as_usize().unwrap() >= 2);

        stop.store(true, Ordering::SeqCst);
        handle.join().unwrap();
    }

    #[test]
    fn batched_rhs_query_over_tcp() {
        let (addr, stop, handle) = start_server();
        let mut client = Client::connect(addr).unwrap();
        let reg = client
            .call(r#"{"cmd":"register","profile":"exp","n":128,"d":16,"seed":4,"name":"blk"}"#)
            .unwrap();
        assert_eq!(reg.get("ok").unwrap().as_bool(), Some(true), "{reg:?}");
        let model = reg.get("model").unwrap().as_usize().unwrap();

        let b1: Vec<f64> = (0..128).map(|i| (i as f64 * 0.05).sin()).collect();
        let b2: Vec<f64> = (0..128).map(|i| (i as f64 * 0.03).cos()).collect();
        let q = client
            .call(&format!(
                r#"{{"cmd":"query","model":{model},"nu":0.5,"bs":[{b1:?},{b2:?}],"include_x":true}}"#
            ))
            .unwrap();
        assert_eq!(q.get("ok").unwrap().as_bool(), Some(true), "{q:?}");
        let batch = q.get("batch").unwrap().as_arr().unwrap();
        assert_eq!(batch.len(), 2);
        for entry in batch {
            assert_eq!(entry.get("converged").unwrap().as_bool(), Some(true));
            assert_eq!(entry.get("nu").unwrap().as_f64(), Some(0.5));
            assert_eq!(entry.get("x").unwrap().as_arr().unwrap().len(), 16);
        }
        assert!(q.get("m").unwrap().as_usize().unwrap() >= 1);

        // Malformed batches answer the standard error shape.
        let bad = client
            .call(&format!(r#"{{"cmd":"query","model":{model},"bs":[[1.0,2.0]]}}"#))
            .unwrap();
        assert_eq!(bad.get("ok").unwrap().as_bool(), Some(false), "short rhs rejected");
        let combined = client
            .call(&format!(r#"{{"cmd":"query","model":{model},"bs":[{b1:?}],"nus":[1.0,0.1]}}"#))
            .unwrap();
        assert_eq!(combined.get("ok").unwrap().as_bool(), Some(false));

        stop.store(true, Ordering::SeqCst);
        handle.join().unwrap();
    }

    #[test]
    fn append_roundtrip_over_tcp() {
        let (addr, stop, handle) = start_server();
        let mut client = Client::connect(addr).unwrap();
        let reg = client
            .call(r#"{"cmd":"register","profile":"exp","n":128,"d":16,"seed":5,"name":"app"}"#)
            .unwrap();
        assert_eq!(reg.get("ok").unwrap().as_bool(), Some(true), "{reg:?}");
        let model = reg.get("model").unwrap().as_usize().unwrap();
        let bytes0 = reg.get("bytes").unwrap().as_usize().unwrap();

        // Warm the session so the append exercises the incremental
        // sketch/factorization refresh, not just data growth.
        let q = client
            .call(&format!(r#"{{"cmd":"query","model":{model},"nu":0.5}}"#))
            .unwrap();
        assert_eq!(q.get("ok").unwrap().as_bool(), Some(true), "{q:?}");
        let m0 = q.get("m").unwrap().as_usize().unwrap();

        let app = client
            .call(&format!(
                r#"{{"cmd":"append","model":{model},"rows":2,"cols":16,
                     "triplets":[[0,0,0.5],[0,5,1.0],[1,3,-0.25]],"b":[0.1,0.2]}}"#
                    .replace('\n', " ")
            ))
            .unwrap();
        assert_eq!(app.get("ok").unwrap().as_bool(), Some(true), "{app:?}");
        assert_eq!(app.get("rows_added").unwrap().as_usize(), Some(2));
        assert_eq!(app.get("n").unwrap().as_usize(), Some(130));
        assert_eq!(app.get("m").unwrap().as_usize(), Some(m0), "append leaves m alone");
        assert_eq!(app.get("refreshed").unwrap().as_bool(), Some(true));
        assert!(app.get("bytes").unwrap().as_usize().unwrap() > bytes0);

        // The model keeps answering queries against the grown data.
        let q2 = client
            .call(&format!(r#"{{"cmd":"query","model":{model},"nu":0.5}}"#))
            .unwrap();
        assert_eq!(q2.get("ok").unwrap().as_bool(), Some(true), "{q2:?}");
        assert_eq!(
            q2.get("result").unwrap().get("converged").unwrap().as_bool(),
            Some(true)
        );

        // Lazy appends defer the refresh to the next query.
        let lazy = client
            .call(&format!(
                r#"{{"cmd":"append","model":{model},"rows":1,"cols":16,
                     "triplets":[[0,2,1.5]],"b":[0.3],"refresh":"lazy"}}"#
                    .replace('\n', " ")
            ))
            .unwrap();
        assert_eq!(lazy.get("ok").unwrap().as_bool(), Some(true), "{lazy:?}");
        assert_eq!(lazy.get("n").unwrap().as_usize(), Some(131));
        assert_eq!(lazy.get("refreshed").unwrap().as_bool(), Some(false));

        // A shape-mismatched delta answers the standard error shape and
        // leaves the model intact.
        let bad = client
            .call(&format!(
                r#"{{"cmd":"append","model":{model},"rows":1,"cols":4,
                     "triplets":[[0,0,1.0]],"b":[1.0]}}"#
                    .replace('\n', " ")
            ))
            .unwrap();
        assert_eq!(bad.get("ok").unwrap().as_bool(), Some(false), "{bad:?}");
        let q3 = client
            .call(&format!(r#"{{"cmd":"query","model":{model},"nu":0.5}}"#))
            .unwrap();
        assert_eq!(q3.get("ok").unwrap().as_bool(), Some(true), "{q3:?}");

        // Appends are counted separately from queries in the metrics.
        let metrics = client.call(r#"{"cmd":"metrics"}"#).unwrap();
        let reg_stats = metrics.get("registry").unwrap();
        assert_eq!(reg_stats.get("appends").unwrap().as_usize(), Some(3));
        assert_eq!(reg_stats.get("queries").unwrap().as_usize(), Some(3));

        // Appending to an evicted model is an unknown-model error.
        client.call(&format!(r#"{{"cmd":"evict","model":{model}}}"#)).unwrap();
        let gone = client
            .call(&format!(
                r#"{{"cmd":"append","model":{model},"rows":1,"cols":16,
                     "triplets":[[0,0,1.0]],"b":[1.0]}}"#
                    .replace('\n', " ")
            ))
            .unwrap();
        assert_eq!(gone.get("ok").unwrap().as_bool(), Some(false));
        assert!(gone.get("error").unwrap().as_str().unwrap().contains("unknown model"));

        stop.store(true, Ordering::SeqCst);
        handle.join().unwrap();
    }

    #[test]
    fn malformed_requests_get_errors() {
        let (addr, stop, handle) = start_server();
        let mut client = Client::connect(addr).unwrap();
        let resp = client.call("garbage").unwrap();
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false));
        let resp = client.call(r#"{"cmd":"status","job":12345}"#).unwrap();
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false));
        stop.store(true, Ordering::SeqCst);
        handle.join().unwrap();
    }

    #[test]
    fn durable_server_recovers_models_across_restart() {
        let state_dir = std::env::temp_dir()
            .join(format!("effdim-server-durable-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&state_dir);
        let config = || ServerConfig {
            state_dir: Some(state_dir.clone()),
            durability: DurabilityPolicy::Strict,
            ..ServerConfig::default()
        };
        let model = {
            let (addr, _stop, handle) = start_with_config(config());
            let mut client = Client::connect(addr).unwrap();
            let reg = client
                .call(r#"{"cmd":"register","profile":"exp","n":128,"d":16,"seed":8,"name":"dur"}"#)
                .unwrap();
            assert_eq!(reg.get("ok").unwrap().as_bool(), Some(true), "{reg:?}");
            let model = reg.get("model").unwrap().as_usize().unwrap();
            // Health/metrics expose the durability surface.
            let h = client.call(r#"{"cmd":"health"}"#).unwrap();
            assert_eq!(h.get("durability").unwrap().as_str(), Some("strict"));
            assert!(h.get("dirty_models").is_some());
            assert!(h.get("wal_lag_bytes").is_some());
            // An append rides the WAL; the explicit snapshot absorbs it.
            let app = client
                .call(&format!(
                    r#"{{"cmd":"append","model":{model},"rows":1,"cols":16,"triplets":[[0,3,1.0]],"b":[0.5]}}"#
                ))
                .unwrap();
            assert_eq!(app.get("ok").unwrap().as_bool(), Some(true), "{app:?}");
            let snap = client.call(r#"{"cmd":"snapshot"}"#).unwrap();
            assert_eq!(snap.get("ok").unwrap().as_bool(), Some(true), "{snap:?}");
            assert_eq!(snap.get("snapshotted").unwrap().as_usize(), Some(1));
            assert_eq!(snap.get("wal_lag_bytes").unwrap().as_usize(), Some(0));
            let resp = client.call(r#"{"cmd":"shutdown"}"#).unwrap();
            assert_eq!(resp.get("stopping").unwrap().as_bool(), Some(true));
            handle.join().unwrap();
            model
        };
        // Restart over the same state dir: the model answers under its
        // old id, bitwise-identically to a never-killed twin (all its
        // mutations were the snapshotted register + the WAL'd append).
        let (addr, stop, handle) = start_with_config(config());
        let mut client = Client::connect(addr).unwrap();
        let q = client
            .call(&format!(r#"{{"cmd":"query","model":{model},"nu":0.5,"include_x":true}}"#))
            .unwrap();
        assert_eq!(q.get("ok").unwrap().as_bool(), Some(true), "{q:?}");
        let x_after: Vec<f64> = q
            .get("result").unwrap().get("x").unwrap()
            .as_arr().unwrap()
            .iter().map(|v| v.as_f64().unwrap()).collect();
        let x_twin = {
            use crate::solvers::session::{AppendRefresh, ModelSession};
            let workload = super::super::job::Workload::Synthetic {
                profile: "exp".into(), n: 128, d: 16, seed: 8,
            };
            let (a, b) = workload.materialize().unwrap();
            let mut twin = ModelSession::new(
                Arc::new(a), b, crate::sketch::SketchKind::Gaussian, 8,
            ).unwrap();
            let delta = crate::linalg::sparse::CsrMatrix::from_triplets(1, 16, &[(0, 3, 1.0)]);
            twin.append(crate::linalg::Operand::Sparse(delta), vec![0.5], AppendRefresh::Eager)
                .unwrap();
            twin.solve(0.5, 1e-8).unwrap().x
        };
        assert_eq!(
            x_twin.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            x_after.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            "recovered model must answer bitwise-identically to a never-killed twin"
        );
        // Purge makes eviction permanent — no reload-on-demand.
        let ev = client
            .call(&format!(r#"{{"cmd":"evict","model":{model},"purge":true}}"#))
            .unwrap();
        assert_eq!(ev.get("purged").unwrap().as_bool(), Some(true), "{ev:?}");
        let gone = client.call(&format!(r#"{{"cmd":"query","model":{model},"nu":0.5}}"#)).unwrap();
        assert_eq!(gone.get("ok").unwrap().as_bool(), Some(false));
        stop.store(true, Ordering::SeqCst);
        handle.join().unwrap();
        let _ = std::fs::remove_dir_all(&state_dir);
    }

    #[test]
    fn snapshot_without_state_dir_errors_cleanly() {
        let (addr, stop, handle) = start_server();
        let mut client = Client::connect(addr).unwrap();
        let resp = client.call(r#"{"cmd":"snapshot"}"#).unwrap();
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false), "{resp:?}");
        assert!(resp.get("error").unwrap().as_str().unwrap().contains("state dir"));
        stop.store(true, Ordering::SeqCst);
        handle.join().unwrap();
    }

    #[test]
    fn shutdown_command_stops_server() {
        let (addr, _stop, handle) = start_server();
        let mut client = Client::connect(addr).unwrap();
        let resp = client.call(r#"{"cmd":"shutdown"}"#).unwrap();
        assert_eq!(resp.get("stopping").unwrap().as_bool(), Some(true));
        handle.join().unwrap();
    }
}
