//! Model registry: named problems with cached cross-query solver state.
//!
//! A client registers a problem once (`{"cmd":"register", ...}`) and then
//! issues many cheap queries against the returned model id — solves at
//! any `nu` (warm-started), batched regularization paths, alternate
//! right-hand sides, and predictions — all served from one
//! [`ModelSession`] per model: the data operand is held once in an `Arc`,
//! the grown sketch and the Woodbury/Cholesky factors survive between
//! queries, and repeat queries cost `O(m^2 d)` or less instead of the
//! from-scratch `O(n d m)`.
//!
//! Memory is bounded by a **byte budget**: every model's approximate
//! footprint ([`ModelSession::approx_bytes`]) is tracked, and when the
//! total exceeds the budget the least-recently-used models are evicted
//! (the model being registered or queried is never the victim of its own
//! request; a single model larger than the whole budget is admitted and
//! simply never shares the registry). Evicted ids return a clean
//! `unknown model` error — clients re-register.
//!
//! Locking: the registry map is one mutex held only for id lookup /
//! insert / evict bookkeeping; each model's session has its own mutex, so
//! queries against different models run fully in parallel while queries
//! against one model serialize (the session mutates its sketch state).
//! Eviction only removes the map entry — an in-flight query holds an
//! `Arc` to the entry and completes normally.

use crate::linalg::Operand;
use crate::sketch::SketchKind;
use crate::solvers::session::ModelSession;
use crate::util::json::Json;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// Monotonic model identifier (shares the id space style of
/// [`super::job::JobId`] but counts independently).
pub type ModelId = u64;

/// Default registry byte budget: 512 MiB of model state.
pub const DEFAULT_BYTE_BUDGET: usize = 512 << 20;

/// One registered model: metadata plus its mutex-guarded session.
pub struct ModelEntry {
    /// Registry-assigned id.
    pub id: ModelId,
    /// Client-supplied name (defaults to the workload description).
    pub name: String,
    /// The reusable solver session; lock to query.
    pub session: Mutex<ModelSession>,
    /// Logical LRU clock value of the last touch.
    last_used: AtomicU64,
    /// Cached `approx_bytes` of the session, refreshed after each query
    /// (sessions grow); reading it must not require the session lock.
    bytes: AtomicUsize,
}

struct Inner {
    models: HashMap<ModelId, Arc<ModelEntry>>,
    next_id: ModelId,
    clock: u64,
}

/// The registry itself. Cheap to share behind an `Arc`.
pub struct Registry {
    inner: Mutex<Inner>,
    byte_budget: usize,
    /// Running sum of the live models' byte estimates, maintained on
    /// register / evict / byte refresh so the per-query budget check is
    /// O(1) instead of an O(models) re-sum under the shared lock.
    bytes_total: AtomicUsize,
    /// Models registered over the registry's lifetime.
    pub registered: AtomicU64,
    /// Models evicted (explicitly or by byte-budget pressure).
    pub evicted: AtomicU64,
    /// Queries answered (solve/path/rhs/predict, cache hits included).
    pub queries: AtomicU64,
    /// Streaming appends applied (`{"cmd":"append"}`); counted separately
    /// from queries — an ingest is not a solve.
    pub appends: AtomicU64,
}

impl Registry {
    /// Create a registry with the given byte budget (see
    /// [`DEFAULT_BYTE_BUDGET`]).
    pub fn new(byte_budget: usize) -> Self {
        Self {
            inner: Mutex::new(Inner { models: HashMap::new(), next_id: 1, clock: 0 }),
            byte_budget,
            bytes_total: AtomicUsize::new(0),
            registered: AtomicU64::new(0),
            evicted: AtomicU64::new(0),
            queries: AtomicU64::new(0),
            appends: AtomicU64::new(0),
        }
    }

    /// Register a problem; returns the model entry (its `id` goes back to
    /// the client). May evict LRU models to fit the budget.
    pub fn register(
        &self,
        name: String,
        a: Operand,
        b: Vec<f64>,
        kind: SketchKind,
        seed: u64,
    ) -> Result<Arc<ModelEntry>, String> {
        let session = ModelSession::new(Arc::new(a), b, kind, seed)?;
        let bytes = session.approx_bytes();
        let entry = {
            let mut inner = self.inner.lock().unwrap();
            let id = inner.next_id;
            inner.next_id += 1;
            inner.clock += 1;
            let entry = Arc::new(ModelEntry {
                id,
                name,
                session: Mutex::new(session),
                last_used: AtomicU64::new(inner.clock),
                bytes: AtomicUsize::new(bytes),
            });
            inner.models.insert(id, Arc::clone(&entry));
            self.bytes_total.fetch_add(bytes, Ordering::Relaxed);
            entry
        };
        self.registered.fetch_add(1, Ordering::Relaxed);
        self.enforce_budget(entry.id);
        Ok(entry)
    }

    /// Look up a model and bump its LRU position. `None` for unknown /
    /// evicted ids.
    pub fn touch(&self, id: ModelId) -> Option<Arc<ModelEntry>> {
        let mut inner = self.inner.lock().unwrap();
        inner.clock += 1;
        let clock = inner.clock;
        inner.models.get(&id).map(|e| {
            e.last_used.store(clock, Ordering::Relaxed);
            Arc::clone(e)
        })
    }

    /// The standard "no such model" error (registration expired or never
    /// happened).
    pub fn unknown(id: ModelId) -> String {
        format!("unknown model {id} (never registered, or evicted — re-register)")
    }

    /// Record a finished query against `entry`: refresh its byte estimate
    /// (sessions grow) and re-enforce the budget, never evicting `entry`
    /// itself. The brief map-lock hold is a membership check plus an O(1)
    /// delta update — solves themselves run outside this lock.
    pub fn note_query(&self, entry: &ModelEntry, session: &ModelSession) {
        self.queries.fetch_add(1, Ordering::Relaxed);
        self.refresh_bytes(entry, session);
    }

    /// Record a finished streaming append against `entry`: the operand,
    /// `A^T b`, sketch rows and (pending or refreshed) factorization all
    /// grew, so the byte estimate is recharged and the LRU budget
    /// re-evaluated immediately — an append can evict colder models, but
    /// never the model being appended to. Counted as an ingest, not a
    /// query.
    pub fn note_append(&self, entry: &ModelEntry, session: &ModelSession) {
        self.appends.fetch_add(1, Ordering::Relaxed);
        self.refresh_bytes(entry, session);
    }

    /// Shared byte re-accounting: swap in the session's fresh
    /// `approx_bytes`, O(1)-update the running total under the map lock,
    /// then enforce the budget without evicting `entry` itself.
    fn refresh_bytes(&self, entry: &ModelEntry, session: &ModelSession) {
        let new = session.approx_bytes();
        {
            let inner = self.inner.lock().unwrap();
            // A concurrently evicted model must not perturb the running
            // total its removal already subtracted.
            if inner.models.contains_key(&entry.id) {
                let old = entry.bytes.swap(new, Ordering::Relaxed);
                if new >= old {
                    self.bytes_total.fetch_add(new - old, Ordering::Relaxed);
                } else {
                    self.bytes_total.fetch_sub(old - new, Ordering::Relaxed);
                }
            }
        }
        self.enforce_budget(entry.id);
    }

    /// Explicitly remove a model. Returns `false` for unknown ids.
    pub fn evict(&self, id: ModelId) -> bool {
        let removed = self.inner.lock().unwrap().models.remove(&id);
        match removed {
            Some(e) => {
                self.bytes_total.fetch_sub(e.bytes.load(Ordering::Relaxed), Ordering::Relaxed);
                self.evicted.fetch_add(1, Ordering::Relaxed);
                true
            }
            None => false,
        }
    }

    /// Number of live models.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().models.len()
    }

    /// Whether no models are registered.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Sum of the models' approximate byte footprints (running total;
    /// O(1)).
    pub fn total_bytes(&self) -> usize {
        self.bytes_total.load(Ordering::Relaxed)
    }

    /// Evict least-recently-used models until the total fits the budget.
    /// `protect` (the model serving the current request) is never
    /// evicted. Under budget this is a lock-free O(1) check; the LRU
    /// scan only runs while actually evicting.
    fn enforce_budget(&self, protect: ModelId) {
        if self.bytes_total.load(Ordering::Relaxed) <= self.byte_budget {
            return;
        }
        let mut inner = self.inner.lock().unwrap();
        let mut evicted = 0u64;
        while self.bytes_total.load(Ordering::Relaxed) > self.byte_budget {
            let victim = inner
                .models
                .values()
                .filter(|e| e.id != protect)
                .min_by_key(|e| e.last_used.load(Ordering::Relaxed))
                .map(|e| e.id);
            match victim {
                Some(id) => {
                    if let Some(e) = inner.models.remove(&id) {
                        self.bytes_total
                            .fetch_sub(e.bytes.load(Ordering::Relaxed), Ordering::Relaxed);
                    }
                    evicted += 1;
                }
                // Only the protected model is left; a single over-budget
                // model is admitted (documented in the module docs).
                None => break,
            }
        }
        if evicted > 0 {
            self.evicted.fetch_add(evicted, Ordering::Relaxed);
        }
    }

    /// Listing for the `models` wire command.
    pub fn models_json(&self) -> Json {
        let mut entries: Vec<Arc<ModelEntry>> =
            self.inner.lock().unwrap().models.values().cloned().collect();
        entries.sort_by_key(|e| e.id);
        Json::Arr(
            entries
                .iter()
                .map(|e| {
                    // Shape/stat fields come from the session; skip (rather
                    // than block on) models busy with a long query.
                    let detail = e.session.try_lock().ok().map(|s| {
                        let (queries, hits) = s.query_stats();
                        (s.n(), s.d(), s.m(), s.kind(), queries, hits)
                    });
                    let mut fields = vec![
                        ("model", Json::from(e.id)),
                        ("name", Json::from(e.name.clone())),
                        ("bytes", Json::from(e.bytes.load(Ordering::Relaxed))),
                    ];
                    if let Some((n, d, m, kind, queries, hits)) = detail {
                        fields.extend([
                            ("n", Json::from(n)),
                            ("d", Json::from(d)),
                            ("m", Json::from(m)),
                            ("sketch", Json::from(kind.to_string())),
                            ("queries", Json::from(queries)),
                            ("cache_hits", Json::from(hits)),
                        ]);
                    }
                    Json::obj(fields)
                })
                .collect(),
        )
    }

    /// Counter snapshot merged into the `metrics` wire response.
    pub fn stats_json(&self) -> Json {
        Json::obj(vec![
            ("models", Json::from(self.len())),
            ("model_bytes", Json::from(self.total_bytes())),
            ("byte_budget", Json::from(self.byte_budget)),
            ("registered", Json::from(self.registered.load(Ordering::Relaxed))),
            ("evicted", Json::from(self.evicted.load(Ordering::Relaxed))),
            ("queries", Json::from(self.queries.load(Ordering::Relaxed))),
            ("appends", Json::from(self.appends.load(Ordering::Relaxed))),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    fn register_one(reg: &Registry, n: usize, d: usize, seed: u64) -> ModelId {
        let ds = synthetic::exponential_decay(n, d, seed);
        reg.register(format!("m{seed}"), ds.a, ds.b, SketchKind::Gaussian, seed)
            .unwrap()
            .id
    }

    #[test]
    fn register_touch_query_evict_roundtrip() {
        let reg = Registry::new(DEFAULT_BYTE_BUDGET);
        let id = register_one(&reg, 128, 16, 1);
        assert_eq!(reg.len(), 1);
        let entry = reg.touch(id).expect("registered model");
        let sol = {
            let mut s = entry.session.lock().unwrap();
            let sol = s.solve(0.5, 1e-8).unwrap();
            reg.note_query(&entry, &s);
            sol
        };
        assert!(sol.report.converged);
        assert_eq!(reg.queries.load(Ordering::Relaxed), 1);
        assert!(reg.evict(id));
        assert!(reg.touch(id).is_none());
        assert!(!reg.evict(id));
        assert!(reg.is_empty());
    }

    #[test]
    fn lru_eviction_under_byte_pressure() {
        // Budget fits roughly two 64x16 dense models (~8 KiB operand each
        // plus session state); a third registration must evict the LRU.
        let one_model = {
            let probe = Registry::new(usize::MAX);
            let id = register_one(&probe, 64, 16, 9);
            probe.touch(id).unwrap().bytes.load(Ordering::Relaxed)
        };
        let reg = Registry::new(one_model * 2 + one_model / 2);
        let a = register_one(&reg, 64, 16, 1);
        let b = register_one(&reg, 64, 16, 2);
        // Touch `a` so `b` is the LRU victim.
        reg.touch(a).unwrap();
        let c = register_one(&reg, 64, 16, 3);
        assert_eq!(reg.len(), 2);
        assert!(reg.touch(a).is_some(), "recently used model survived");
        assert!(reg.touch(b).is_none(), "LRU model evicted");
        assert!(reg.touch(c).is_some(), "new model admitted");
        assert_eq!(reg.evicted.load(Ordering::Relaxed), 1);
        assert!(reg.total_bytes() <= one_model * 2 + one_model / 2);
    }

    #[test]
    fn append_can_evict_colder_model() {
        use crate::solvers::session::AppendRefresh;
        // Same probe/budget setup as the LRU test: two 64x16 models fit,
        // with half a model of slack.
        let one_model = {
            let probe = Registry::new(usize::MAX);
            let id = register_one(&probe, 64, 16, 9);
            probe.touch(id).unwrap().bytes.load(Ordering::Relaxed)
        };
        let reg = Registry::new(one_model * 2 + one_model / 2);
        let hot = register_one(&reg, 64, 16, 1);
        let cold = register_one(&reg, 64, 16, 2);
        assert_eq!(reg.len(), 2, "both models fit before the append");
        // Stream a delta much larger than the slack into `hot`. The byte
        // recharge in `note_append` must re-run the budget check and evict
        // the colder model -- never the model being appended to.
        let entry = reg.touch(hot).unwrap();
        {
            let ds = synthetic::exponential_decay(1024, 16, 3);
            let mut s = entry.session.lock().unwrap();
            s.append(ds.a.into(), ds.b, AppendRefresh::Eager).unwrap();
            reg.note_append(&entry, &s);
        }
        assert!(reg.touch(hot).is_some(), "appended model survives");
        assert!(reg.touch(cold).is_none(), "colder model evicted by append");
        assert_eq!(reg.evicted.load(Ordering::Relaxed), 1);
        assert_eq!(reg.appends.load(Ordering::Relaxed), 1);
        assert_eq!(reg.queries.load(Ordering::Relaxed), 0, "append is not a query");
        let stats = reg.stats_json();
        assert_eq!(stats.get("appends").unwrap().as_usize(), Some(1));
        assert!(
            entry.bytes.load(Ordering::Relaxed) > one_model,
            "append recharged the cached byte estimate"
        );
    }

    #[test]
    fn single_over_budget_model_is_admitted() {
        let reg = Registry::new(1); // absurdly small budget
        let id = register_one(&reg, 64, 8, 4);
        assert!(reg.touch(id).is_some(), "lone model must not evict itself");
        assert_eq!(reg.len(), 1);
        // A second registration makes the first the victim.
        let id2 = register_one(&reg, 64, 8, 5);
        assert_eq!(reg.len(), 1);
        assert!(reg.touch(id).is_none());
        assert!(reg.touch(id2).is_some());
    }

    #[test]
    fn listing_and_stats_shapes() {
        let reg = Registry::new(DEFAULT_BYTE_BUDGET);
        register_one(&reg, 64, 8, 6);
        register_one(&reg, 64, 8, 7);
        let listing = reg.models_json();
        let arr = listing.as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        assert!(arr[0].get("model").unwrap().as_usize().unwrap() <
                arr[1].get("model").unwrap().as_usize().unwrap());
        assert_eq!(arr[0].get("sketch").unwrap().as_str(), Some("gaussian"));
        let stats = reg.stats_json();
        assert_eq!(stats.get("models").unwrap().as_usize(), Some(2));
        assert_eq!(stats.get("registered").unwrap().as_usize(), Some(2));
    }

    #[test]
    fn ids_are_never_reused_after_eviction() {
        let reg = Registry::new(DEFAULT_BYTE_BUDGET);
        let a = register_one(&reg, 64, 8, 1);
        reg.evict(a);
        let b = register_one(&reg, 64, 8, 2);
        assert!(b > a, "model ids must stay monotonic");
    }
}
