//! Model registry: named problems with cached cross-query solver state.
//!
//! A client registers a problem once (`{"cmd":"register", ...}`) and then
//! issues many cheap queries against the returned model id — solves at
//! any `nu` (warm-started), batched regularization paths, alternate
//! right-hand sides, and predictions — all served from one
//! [`ModelSession`] per model: the data operand is held once in an `Arc`,
//! the grown sketch and the Woodbury/Cholesky factors survive between
//! queries, and repeat queries cost `O(m^2 d)` or less instead of the
//! from-scratch `O(n d m)`.
//!
//! Memory is bounded by a **byte budget**: every model's approximate
//! footprint ([`ModelSession::approx_bytes`]) is tracked, and when the
//! total exceeds the budget the least-recently-used models are evicted
//! (the model being registered or queried is never the victim of its own
//! request; a single model larger than the whole budget is admitted and
//! simply never shares the registry). Evicted ids return a clean
//! `unknown model` error — clients re-register.
//!
//! Locking: the registry map is one mutex held only for id lookup /
//! insert / evict bookkeeping; each model's session has its own mutex, so
//! queries against different models run fully in parallel while queries
//! against one model serialize (the session mutates its sketch state).
//! Eviction only removes the map entry — an in-flight query holds an
//! `Arc` to the entry and completes normally.
//!
//! **Lock-free reads:** on top of the session mutex, every entry
//! publishes an immutable [`SessionSnapshot`] through an RCU cell
//! ([`crate::util::rcu::RcuCell`]). Read-only queries — exact-repeat
//! solves and predicts over cached solutions — are answered straight
//! from [`ModelEntry::snapshot`] without ever acquiring the session
//! mutex, so unlimited readers of one hot model overlap freely while a
//! writer (solve / append / re-key) mutates the session under its lock
//! and republishes via [`ModelEntry::publish`] **only after the mutation
//! commits**. A failed or rolled-back writer publishes nothing, so
//! readers can never observe a partial state; a reader holding an old
//! snapshot keeps getting that generation's bitwise answers for as long
//! as it holds the `Arc`.
//!
//! **Durability** (`serve --state-dir`): with a [`Store`] attached,
//! registration writes an initial checksummed snapshot, every eviction
//! becomes a *spill* — the model's pending appends are flushed and its
//! state snapshotted before the RAM entry is dropped — and a `touch` of a
//! spilled id transparently reloads the model from disk instead of
//! answering `unknown model`. Explicit `evict` with `"purge":true`
//! deletes the on-disk state too. At startup [`Registry::recover`]
//! repopulates the map from the store (snapshot + WAL replay), keeping
//! the original model ids.

use crate::linalg::Operand;
use crate::persist::Store;
use crate::sketch::SketchKind;
use crate::solvers::session::{ModelSession, SessionSnapshot};
use crate::util::json::Json;
use crate::util::{failpoint, rcu::RcuCell};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// Monotonic model identifier (shares the id space style of
/// [`super::job::JobId`] but counts independently).
pub type ModelId = u64;

/// Default registry byte budget: 512 MiB of model state.
pub const DEFAULT_BYTE_BUDGET: usize = 512 << 20;

/// One registered model: metadata plus its mutex-guarded session.
pub struct ModelEntry {
    /// Registry-assigned id.
    pub id: ModelId,
    /// Client-supplied name (defaults to the workload description).
    pub name: String,
    /// The reusable solver session; lock to query.
    pub session: Mutex<ModelSession>,
    /// The published read-only view (see the module docs); loaded
    /// lock-free by [`ModelEntry::snapshot`], swapped by
    /// [`ModelEntry::publish`] after each committed mutation.
    snap: RcuCell<SessionSnapshot>,
    /// Queries answered entirely from the published snapshot (no session
    /// lock). Counted here because the snapshot itself is immutable.
    pub snap_queries: AtomicU64,
    /// Snapshot-path queries that hit the cached-solution fast path
    /// (kept separate from [`ModelEntry::frozen_solves`]: a hit copies a
    /// cached vector, a frozen solve runs the full iteration lock-free).
    pub snap_hits: AtomicU64,
    /// Uncached solves answered entirely through the frozen read lane
    /// (pinned panel + pure per-`nu` re-key; no session lock, no growth).
    pub frozen_solves: AtomicU64,
    /// Frozen-lane attempts that returned `NeedsGrowth` (or a recovery
    /// condition) and fell back to the mutex lane for this model.
    pub frozen_fallbacks: AtomicU64,
    /// Logical LRU clock value of the last touch.
    last_used: AtomicU64,
    /// Cached `approx_bytes` of the session, refreshed after each query
    /// (sessions grow); reading it must not require the session lock.
    bytes: AtomicUsize,
}

impl ModelEntry {
    /// Clone the currently published snapshot handle — **no mutex**, two
    /// atomic RMWs and an `Arc` clone (see [`crate::util::rcu::RcuCell`]).
    /// This is the whole read path: callers answer from the returned
    /// snapshot and never touch [`ModelEntry::session`].
    pub fn snapshot(&self) -> Arc<SessionSnapshot> {
        self.snap.load()
    }

    /// Publish the session's current state as the new snapshot. Call
    /// **after** a mutation commits, while still holding the session
    /// lock (the `&mut ModelSession` argument enforces exactly that) —
    /// publishing under the lock keeps generation order identical to
    /// commit order.
    ///
    /// The `session.publish` failpoint fires *before* the swap: an
    /// injected failure here models a writer dying between commit and
    /// publish — the previous snapshot stays live and fully consistent,
    /// and the next successful publish covers the skipped one (readers
    /// see the committed state then, one generation late). The swap
    /// itself is a single atomic store, so there is no partially
    /// published state to observe, ever.
    pub fn publish(&self, session: &mut ModelSession) -> Result<(), String> {
        let snap = session.snapshot();
        failpoint::check("session.publish")?;
        self.snap.store(snap);
        Ok(())
    }
}

struct Inner {
    models: HashMap<ModelId, Arc<ModelEntry>>,
    next_id: ModelId,
    clock: u64,
}

/// The registry itself. Cheap to share behind an `Arc`.
pub struct Registry {
    inner: Mutex<Inner>,
    byte_budget: usize,
    /// Durable backing store (`serve --state-dir`); `None` = RAM-only.
    store: Option<Arc<Store>>,
    /// Running sum of the live models' byte estimates, maintained on
    /// register / evict / byte refresh so the per-query budget check is
    /// O(1) instead of an O(models) re-sum under the shared lock.
    bytes_total: AtomicUsize,
    /// Models registered over the registry's lifetime.
    pub registered: AtomicU64,
    /// Models evicted (explicitly or by byte-budget pressure).
    pub evicted: AtomicU64,
    /// Queries answered (solve/path/rhs/predict, cache hits included).
    pub queries: AtomicU64,
    /// Streaming appends applied (`{"cmd":"append"}`); counted separately
    /// from queries — an ingest is not a solve.
    pub appends: AtomicU64,
    /// Uncached solves answered through the frozen read lane across all
    /// models (no session lock; see [`ModelEntry::frozen_solves`]).
    pub frozen_solves: AtomicU64,
    /// Frozen-lane attempts that deferred with `NeedsGrowth` and were
    /// re-run on the mutex lane (each such query is counted once, by the
    /// mutex lane's [`Registry::note_query`]).
    pub frozen_fallbacks: AtomicU64,
}

impl Registry {
    /// Create a RAM-only registry with the given byte budget (see
    /// [`DEFAULT_BYTE_BUDGET`]).
    pub fn new(byte_budget: usize) -> Self {
        Self::build(byte_budget, None)
    }

    /// Create a registry backed by a durable [`Store`]: registrations
    /// snapshot, evictions spill, touches reload. Call
    /// [`Registry::recover`] afterwards to repopulate from disk.
    pub fn with_store(byte_budget: usize, store: Arc<Store>) -> Self {
        Self::build(byte_budget, Some(store))
    }

    fn build(byte_budget: usize, store: Option<Arc<Store>>) -> Self {
        Self {
            inner: Mutex::new(Inner { models: HashMap::new(), next_id: 1, clock: 0 }),
            byte_budget,
            store,
            bytes_total: AtomicUsize::new(0),
            registered: AtomicU64::new(0),
            evicted: AtomicU64::new(0),
            queries: AtomicU64::new(0),
            appends: AtomicU64::new(0),
            frozen_solves: AtomicU64::new(0),
            frozen_fallbacks: AtomicU64::new(0),
        }
    }

    /// The attached durable store, if any.
    pub fn store(&self) -> Option<&Arc<Store>> {
        self.store.as_ref()
    }

    /// Repopulate the registry from the attached store: every model whose
    /// snapshot decodes and whose WAL tail replays comes back under its
    /// **original id** (damaged models are skipped with a warning inside
    /// the store). Fresh ids continue after the largest recovered one.
    /// Returns the number of models recovered.
    pub fn recover(&self) -> Result<usize, String> {
        let store = self.store.as_ref().ok_or("registry has no durable store")?;
        let recovered = store.recover_all()?;
        let count = recovered.len();
        let mut inner = self.inner.lock().unwrap();
        for mut model in recovered {
            let bytes = model.session.approx_bytes();
            inner.clock += 1;
            // Recovery publishes only after the rebuild + WAL replay
            // fully succeeded (damaged models were skipped above), so the
            // first snapshot readers can load is already the complete
            // recovered state — replay never exposes an intermediate.
            let snap = RcuCell::new(model.session.snapshot());
            let entry = Arc::new(ModelEntry {
                id: model.id,
                name: model.name,
                session: Mutex::new(model.session),
                snap,
                snap_queries: AtomicU64::new(0),
                snap_hits: AtomicU64::new(0),
                frozen_solves: AtomicU64::new(0),
                frozen_fallbacks: AtomicU64::new(0),
                last_used: AtomicU64::new(inner.clock),
                bytes: AtomicUsize::new(bytes),
            });
            inner.models.insert(model.id, entry);
            inner.next_id = inner.next_id.max(model.id + 1);
            self.bytes_total.fetch_add(bytes, Ordering::Relaxed);
        }
        Ok(count)
    }

    /// Register a problem; returns the model entry (its `id` goes back to
    /// the client). May evict LRU models to fit the budget.
    pub fn register(
        &self,
        name: String,
        a: Operand,
        b: Vec<f64>,
        kind: SketchKind,
        seed: u64,
    ) -> Result<Arc<ModelEntry>, String> {
        let mut session = ModelSession::new(Arc::new(a), b, kind, seed)?;
        let bytes = session.approx_bytes();
        let snap = RcuCell::new(session.snapshot());
        let entry = {
            let mut inner = self.inner.lock().unwrap();
            let id = inner.next_id;
            inner.next_id += 1;
            inner.clock += 1;
            let entry = Arc::new(ModelEntry {
                id,
                name,
                session: Mutex::new(session),
                snap,
                snap_queries: AtomicU64::new(0),
                snap_hits: AtomicU64::new(0),
                frozen_solves: AtomicU64::new(0),
                frozen_fallbacks: AtomicU64::new(0),
                last_used: AtomicU64::new(inner.clock),
                bytes: AtomicUsize::new(bytes),
            });
            inner.models.insert(id, Arc::clone(&entry));
            self.bytes_total.fetch_add(bytes, Ordering::Relaxed);
            entry
        };
        // Durable registration: the initial snapshot must land before the
        // client's ack — a model that cannot be persisted is not
        // registered at all (rolled back with its disk state purged).
        if let Some(store) = &self.store {
            let outcome = {
                let mut session = entry.session.lock().unwrap();
                store.persist_model(entry.id, &entry.name, &mut session)
            };
            if let Err(e) = outcome {
                if let Some(dead) = self.inner.lock().unwrap().models.remove(&entry.id) {
                    self.bytes_total
                        .fetch_sub(dead.bytes.load(Ordering::Relaxed), Ordering::Relaxed);
                }
                store.drop_model(entry.id, true);
                return Err(format!("cannot persist model: {e}"));
            }
        }
        self.registered.fetch_add(1, Ordering::Relaxed);
        self.enforce_budget(entry.id);
        Ok(entry)
    }

    /// Look up a model and bump its LRU position. `None` for unknown /
    /// purged ids. With a durable store attached, a **spilled** model is
    /// transparently reloaded from its snapshot + WAL (reload-on-demand)
    /// — the map lock is held across the reload so concurrent touches of
    /// the same spilled id resolve to one reload, not two.
    pub fn touch(&self, id: ModelId) -> Option<Arc<ModelEntry>> {
        let mut inner = self.inner.lock().unwrap();
        inner.clock += 1;
        let clock = inner.clock;
        if let Some(e) = inner.models.get(&id) {
            e.last_used.store(clock, Ordering::Relaxed);
            return Some(Arc::clone(e));
        }
        let store = self.store.as_ref()?;
        if !store.has_spilled(id) {
            return None;
        }
        let mut reloaded = match store.load_model(id) {
            Ok(m) => m,
            Err(e) => {
                eprintln!("warning: reload of spilled model {id} failed: {e}");
                return None;
            }
        };
        let bytes = reloaded.session.approx_bytes();
        let snap = RcuCell::new(reloaded.session.snapshot());
        let entry = Arc::new(ModelEntry {
            id,
            name: reloaded.name,
            session: Mutex::new(reloaded.session),
            snap,
            snap_queries: AtomicU64::new(0),
            snap_hits: AtomicU64::new(0),
            frozen_solves: AtomicU64::new(0),
            frozen_fallbacks: AtomicU64::new(0),
            last_used: AtomicU64::new(clock),
            bytes: AtomicUsize::new(bytes),
        });
        inner.models.insert(id, Arc::clone(&entry));
        self.bytes_total.fetch_add(bytes, Ordering::Relaxed);
        drop(inner);
        self.enforce_budget(id);
        Some(entry)
    }

    /// The standard "no such model" error (registration expired or never
    /// happened).
    pub fn unknown(id: ModelId) -> String {
        format!("unknown model {id} (never registered, or evicted — re-register)")
    }

    /// Record a finished query against `entry`: refresh its byte estimate
    /// (sessions grow) and re-enforce the budget, never evicting `entry`
    /// itself. The brief map-lock hold is a membership check plus an O(1)
    /// delta update — solves themselves run outside this lock.
    pub fn note_query(&self, entry: &ModelEntry, session: &ModelSession) {
        self.queries.fetch_add(1, Ordering::Relaxed);
        self.refresh_bytes(entry, session);
    }

    /// Record a query answered entirely from the published snapshot: the
    /// registry-level counter advances (a snapshot hit is still a served
    /// query, so wire metrics stay comparable with the locked path) and
    /// the entry's own atomics record the lock-free hit. No byte refresh
    /// — a read-only answer grows nothing — and no session lock, which is
    /// the point.
    pub fn note_snapshot_query(&self, entry: &ModelEntry) {
        self.queries.fetch_add(1, Ordering::Relaxed);
        entry.snap_queries.fetch_add(1, Ordering::Relaxed);
        entry.snap_hits.fetch_add(1, Ordering::Relaxed);
    }

    /// Record an uncached solve answered entirely through the frozen read
    /// lane. Counts as a served query (wire metrics stay comparable with
    /// the mutex lane) and as a snapshot-path query, but **not** as a
    /// cache hit — the full iteration ran, lock-free. No byte refresh:
    /// the frozen lane mutates nothing, so the session's footprint is
    /// unchanged. The LRU position was already bumped by the
    /// [`Registry::touch`] that resolved the model id — frozen solves
    /// keep a model hot exactly like mutex-lane solves do.
    pub fn note_frozen_solve(&self, entry: &ModelEntry) {
        self.queries.fetch_add(1, Ordering::Relaxed);
        self.frozen_solves.fetch_add(1, Ordering::Relaxed);
        entry.snap_queries.fetch_add(1, Ordering::Relaxed);
        entry.frozen_solves.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a frozen-lane attempt that deferred (`NeedsGrowth`) to the
    /// mutex lane. Only the fallback counters advance — the query itself
    /// is counted once, by the mutex lane's [`Registry::note_query`] when
    /// the writer-path solve finishes.
    pub fn note_frozen_fallback(&self, entry: &ModelEntry) {
        self.frozen_fallbacks.fetch_add(1, Ordering::Relaxed);
        entry.frozen_fallbacks.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a finished streaming append against `entry`: the operand,
    /// `A^T b`, sketch rows and (pending or refreshed) factorization all
    /// grew, so the byte estimate is recharged and the LRU budget
    /// re-evaluated immediately — an append can evict colder models, but
    /// never the model being appended to. Counted as an ingest, not a
    /// query.
    pub fn note_append(&self, entry: &ModelEntry, session: &ModelSession) {
        self.appends.fetch_add(1, Ordering::Relaxed);
        self.refresh_bytes(entry, session);
    }

    /// Shared byte re-accounting: swap in the session's fresh
    /// `approx_bytes` **plus** whatever the published snapshot still
    /// retains beyond the live state
    /// ([`SessionSnapshot::retained_bytes`] — allocation-deduplicated via
    /// `Arc::ptr_eq`, so shared panels/operands are charged once), then
    /// O(1)-update the running total under the map lock and enforce the
    /// budget without evicting `entry` itself. Charging the retained
    /// artifacts matters after growth: a stale snapshot pins the whole
    /// pre-growth panel + engine until the next publish, and a budget
    /// that ignored it would admit more live state than configured.
    fn refresh_bytes(&self, entry: &ModelEntry, session: &ModelSession) {
        let new = session.approx_bytes() + entry.snapshot().retained_bytes(session);
        {
            let inner = self.inner.lock().unwrap();
            // A concurrently evicted model must not perturb the running
            // total its removal already subtracted.
            if inner.models.contains_key(&entry.id) {
                let old = entry.bytes.swap(new, Ordering::Relaxed);
                if new >= old {
                    self.bytes_total.fetch_add(new - old, Ordering::Relaxed);
                } else {
                    self.bytes_total.fetch_sub(old - new, Ordering::Relaxed);
                }
            }
        }
        self.enforce_budget(entry.id);
    }

    /// Explicitly remove a model. Returns `false` for unknown ids. With a
    /// durable store attached the default is a **spill** — pending lazy
    /// appends are flushed and a final snapshot written, so a later touch
    /// reloads the model losslessly; `purge` deletes the on-disk state
    /// too, making the removal permanent.
    pub fn evict(&self, id: ModelId, purge: bool) -> bool {
        let removed = self.inner.lock().unwrap().models.remove(&id);
        match removed {
            Some(e) => {
                self.bytes_total.fetch_sub(e.bytes.load(Ordering::Relaxed), Ordering::Relaxed);
                self.evicted.fetch_add(1, Ordering::Relaxed);
                self.offload(&e, purge);
                true
            }
            None => false,
        }
    }

    /// Offload a just-removed entry's state to the store (no-op without
    /// one). Spilling flushes un-applied lazy append deltas and writes a
    /// final snapshot **before** the RAM entry dies — dropping the entry
    /// without this would discard pending rows that were never folded
    /// into the sketch. Runs outside the map lock; the session is
    /// `try_lock`ed so two threads spilling each other's victims cannot
    /// deadlock — a busy session skips the snapshot (its on-disk
    /// snapshot + WAL already cover every acked append; only cached
    /// solver state is lost).
    fn offload(&self, entry: &ModelEntry, purge: bool) {
        let Some(store) = &self.store else { return };
        if purge {
            store.drop_model(entry.id, true);
            return;
        }
        if let Ok(mut session) = entry.session.try_lock() {
            if let Err(e) = store.persist_model(entry.id, &entry.name, &mut session) {
                eprintln!(
                    "warning: spill snapshot of model {} failed: {e} \
                     (its WAL still covers every acked append)",
                    entry.id
                );
            }
        }
        store.drop_model(entry.id, false);
    }

    /// Number of live models.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().models.len()
    }

    /// Whether no models are registered.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Sum of the models' approximate byte footprints (running total;
    /// O(1)).
    pub fn total_bytes(&self) -> usize {
        self.bytes_total.load(Ordering::Relaxed)
    }

    /// Evict least-recently-used models until the total fits the budget.
    /// `protect` (the model serving the current request) is never
    /// evicted. Under budget this is a lock-free O(1) check; the LRU
    /// scan only runs while actually evicting.
    fn enforce_budget(&self, protect: ModelId) {
        if self.bytes_total.load(Ordering::Relaxed) <= self.byte_budget {
            return;
        }
        let mut victims: Vec<Arc<ModelEntry>> = Vec::new();
        {
            let mut inner = self.inner.lock().unwrap();
            while self.bytes_total.load(Ordering::Relaxed) > self.byte_budget {
                let victim = inner
                    .models
                    .values()
                    .filter(|e| e.id != protect)
                    .min_by_key(|e| e.last_used.load(Ordering::Relaxed))
                    .map(|e| e.id);
                match victim {
                    Some(id) => {
                        if let Some(e) = inner.models.remove(&id) {
                            self.bytes_total
                                .fetch_sub(e.bytes.load(Ordering::Relaxed), Ordering::Relaxed);
                            victims.push(e);
                        }
                    }
                    // Only the protected model is left; a single
                    // over-budget model is admitted (documented in the
                    // module docs).
                    None => break,
                }
            }
        }
        if !victims.is_empty() {
            self.evicted.fetch_add(victims.len() as u64, Ordering::Relaxed);
            // Byte-pressure eviction is always a spill, never a purge —
            // done after releasing the map lock (the spill locks each
            // victim's session).
            for e in &victims {
                self.offload(e, false);
            }
        }
    }

    /// Listing for the `models` wire command.
    pub fn models_json(&self) -> Json {
        let mut entries: Vec<Arc<ModelEntry>> =
            self.inner.lock().unwrap().models.values().cloned().collect();
        entries.sort_by_key(|e| e.id);
        Json::Arr(
            entries
                .iter()
                .map(|e| {
                    // Shape/stat fields come from the session; skip (rather
                    // than block on) models busy with a long query.
                    let detail = e.session.try_lock().ok().map(|s| {
                        let (queries, hits) = s.query_stats();
                        (s.n(), s.d(), s.m(), s.kind(), queries, hits)
                    });
                    let mut fields = vec![
                        ("model", Json::from(e.id)),
                        ("name", Json::from(e.name.clone())),
                        ("bytes", Json::from(e.bytes.load(Ordering::Relaxed))),
                        // Snapshot-path stats come from the entry's own
                        // atomics + RCU cell, so they are reported even
                        // for models busy with a long writer-path query.
                        ("generation", Json::from(e.snapshot().generation())),
                        (
                            "snapshot_queries",
                            Json::from(e.snap_queries.load(Ordering::Relaxed)),
                        ),
                        (
                            "frozen_solves",
                            Json::from(e.frozen_solves.load(Ordering::Relaxed)),
                        ),
                        (
                            "frozen_fallbacks",
                            Json::from(e.frozen_fallbacks.load(Ordering::Relaxed)),
                        ),
                    ];
                    if let Some((n, d, m, kind, queries, hits)) = detail {
                        fields.extend([
                            ("n", Json::from(n)),
                            ("d", Json::from(d)),
                            ("m", Json::from(m)),
                            ("sketch", Json::from(kind.to_string())),
                            ("queries", Json::from(queries)),
                            ("cache_hits", Json::from(hits)),
                        ]);
                    }
                    Json::obj(fields)
                })
                .collect(),
        )
    }

    /// Snapshot every live model (or just `only`) to the durable store,
    /// flushing pending appends and resetting each model's WAL. Returns
    /// the number of models persisted. Errors if no store is attached or
    /// `only` names an unknown model.
    pub fn persist_all(&self, only: Option<ModelId>) -> Result<usize, String> {
        let store = self.store.as_ref().ok_or("server has no state dir (durability is off)")?;
        let entries: Vec<Arc<ModelEntry>> = {
            let inner = self.inner.lock().unwrap();
            match only {
                Some(id) => {
                    vec![inner.models.get(&id).cloned().ok_or_else(|| Self::unknown(id))?]
                }
                None => inner.models.values().cloned().collect(),
            }
        };
        let mut persisted = 0;
        for e in &entries {
            let mut session = e.session.lock().unwrap();
            store.persist_model(e.id, &e.name, &mut session)?;
            persisted += 1;
        }
        store.sync_all()?;
        Ok(persisted)
    }

    /// Number of live models whose solver state has moved past their last
    /// snapshot (a crash now would recover them losslessly but not
    /// solver-state-bitwise). Models busy with an in-flight request are
    /// counted dirty — the request is mutating them.
    pub fn dirty_models(&self) -> usize {
        let Some(store) = &self.store else { return 0 };
        let entries: Vec<Arc<ModelEntry>> =
            self.inner.lock().unwrap().models.values().cloned().collect();
        entries
            .iter()
            .filter(|e| match e.session.try_lock() {
                Ok(s) => store.persisted_epoch(e.id) != Some(s.epoch()),
                Err(_) => true,
            })
            .count()
    }

    /// Counter snapshot merged into the `metrics` wire response. With a
    /// durable store attached, persistence counters ride along.
    pub fn stats_json(&self) -> Json {
        let mut fields = vec![
            ("models", Json::from(self.len())),
            ("model_bytes", Json::from(self.total_bytes())),
            ("byte_budget", Json::from(self.byte_budget)),
            ("registered", Json::from(self.registered.load(Ordering::Relaxed))),
            ("evicted", Json::from(self.evicted.load(Ordering::Relaxed))),
            ("queries", Json::from(self.queries.load(Ordering::Relaxed))),
            ("appends", Json::from(self.appends.load(Ordering::Relaxed))),
            ("frozen_solves", Json::from(self.frozen_solves.load(Ordering::Relaxed))),
            ("frozen_fallbacks", Json::from(self.frozen_fallbacks.load(Ordering::Relaxed))),
        ];
        if let Some(store) = &self.store {
            fields.extend([
                ("durability", Json::from(store.policy().to_string())),
                ("snapshots_written", Json::from(store.snapshots_written.load(Ordering::Relaxed))),
                ("wal_records", Json::from(store.wal_records.load(Ordering::Relaxed))),
                ("wal_lag_bytes", Json::from(store.wal_lag_bytes())),
                ("truncated_tails", Json::from(store.truncated_tails.load(Ordering::Relaxed))),
                ("recovered_models", Json::from(store.recovered_models.load(Ordering::Relaxed))),
                ("spills", Json::from(store.spills.load(Ordering::Relaxed))),
                ("reloads", Json::from(store.reloads.load(Ordering::Relaxed))),
                ("purged", Json::from(store.purged.load(Ordering::Relaxed))),
                ("dirty_models", Json::from(self.dirty_models())),
            ]);
        }
        Json::obj(fields)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    fn register_one(reg: &Registry, n: usize, d: usize, seed: u64) -> ModelId {
        let ds = synthetic::exponential_decay(n, d, seed);
        reg.register(format!("m{seed}"), ds.a, ds.b, SketchKind::Gaussian, seed)
            .unwrap()
            .id
    }

    #[test]
    fn register_touch_query_evict_roundtrip() {
        let reg = Registry::new(DEFAULT_BYTE_BUDGET);
        let id = register_one(&reg, 128, 16, 1);
        assert_eq!(reg.len(), 1);
        let entry = reg.touch(id).expect("registered model");
        let sol = {
            let mut s = entry.session.lock().unwrap();
            let sol = s.solve(0.5, 1e-8).unwrap();
            reg.note_query(&entry, &s);
            sol
        };
        assert!(sol.report.converged);
        assert_eq!(reg.queries.load(Ordering::Relaxed), 1);
        assert!(reg.evict(id, false));
        assert!(reg.touch(id).is_none());
        assert!(!reg.evict(id, false));
        assert!(reg.is_empty());
    }

    #[test]
    fn lru_eviction_under_byte_pressure() {
        // Budget fits roughly two 64x16 dense models (~8 KiB operand each
        // plus session state); a third registration must evict the LRU.
        let one_model = {
            let probe = Registry::new(usize::MAX);
            let id = register_one(&probe, 64, 16, 9);
            probe.touch(id).unwrap().bytes.load(Ordering::Relaxed)
        };
        let reg = Registry::new(one_model * 2 + one_model / 2);
        let a = register_one(&reg, 64, 16, 1);
        let b = register_one(&reg, 64, 16, 2);
        // Touch `a` so `b` is the LRU victim.
        reg.touch(a).unwrap();
        let c = register_one(&reg, 64, 16, 3);
        assert_eq!(reg.len(), 2);
        assert!(reg.touch(a).is_some(), "recently used model survived");
        assert!(reg.touch(b).is_none(), "LRU model evicted");
        assert!(reg.touch(c).is_some(), "new model admitted");
        assert_eq!(reg.evicted.load(Ordering::Relaxed), 1);
        assert!(reg.total_bytes() <= one_model * 2 + one_model / 2);
    }

    #[test]
    fn append_can_evict_colder_model() {
        use crate::solvers::session::AppendRefresh;
        // Same probe/budget setup as the LRU test: two 64x16 models fit,
        // with half a model of slack.
        let one_model = {
            let probe = Registry::new(usize::MAX);
            let id = register_one(&probe, 64, 16, 9);
            probe.touch(id).unwrap().bytes.load(Ordering::Relaxed)
        };
        let reg = Registry::new(one_model * 2 + one_model / 2);
        let hot = register_one(&reg, 64, 16, 1);
        let cold = register_one(&reg, 64, 16, 2);
        assert_eq!(reg.len(), 2, "both models fit before the append");
        // Stream a delta much larger than the slack into `hot`. The byte
        // recharge in `note_append` must re-run the budget check and evict
        // the colder model -- never the model being appended to.
        let entry = reg.touch(hot).unwrap();
        {
            let ds = synthetic::exponential_decay(1024, 16, 3);
            let mut s = entry.session.lock().unwrap();
            s.append(ds.a.into(), ds.b, AppendRefresh::Eager).unwrap();
            reg.note_append(&entry, &s);
        }
        assert!(reg.touch(hot).is_some(), "appended model survives");
        assert!(reg.touch(cold).is_none(), "colder model evicted by append");
        assert_eq!(reg.evicted.load(Ordering::Relaxed), 1);
        assert_eq!(reg.appends.load(Ordering::Relaxed), 1);
        assert_eq!(reg.queries.load(Ordering::Relaxed), 0, "append is not a query");
        let stats = reg.stats_json();
        assert_eq!(stats.get("appends").unwrap().as_usize(), Some(1));
        assert!(
            entry.bytes.load(Ordering::Relaxed) > one_model,
            "append recharged the cached byte estimate"
        );
    }

    #[test]
    fn panel_growth_recharge_counts_retained_snapshot_and_evicts() {
        // Regression for snapshot byte accounting: after a warm solve the
        // published snapshot shares everything with the live state, but a
        // later growth solve leaves the snapshot pinning the whole
        // pre-growth panel + engine. The recharge in `note_query` must
        // charge session + retained-snapshot bytes (deduplicated per
        // allocation) — enough pressure to evict a colder model.
        let warm_bytes = {
            let probe = Registry::new(usize::MAX);
            let id = register_one(&probe, 96, 12, 9);
            let entry = probe.touch(id).unwrap();
            let mut s = entry.session.lock().unwrap();
            s.solve(0.5, 1e-8).unwrap();
            entry.publish(&mut s).unwrap();
            probe.note_query(&entry, &s);
            drop(s);
            entry.bytes.load(Ordering::Relaxed)
        };
        // Both warmed models fit with a sliver of slack.
        let reg = Registry::new(warm_bytes * 2 + warm_bytes / 8);
        let hot = register_one(&reg, 96, 12, 1);
        let cold = register_one(&reg, 96, 12, 2);
        for id in [hot, cold] {
            let entry = reg.touch(id).unwrap();
            let mut s = entry.session.lock().unwrap();
            s.solve(0.5, 1e-8).unwrap();
            entry.publish(&mut s).unwrap();
            reg.note_query(&entry, &s);
        }
        assert_eq!(reg.len(), 2, "both warmed models fit before growth");
        // Make `hot` the protected/most-recent model, then force growth
        // with a much smaller nu. The snapshot published at nu=0.5 is NOT
        // republished, so it retains the pre-growth panel.
        let entry = reg.touch(hot).unwrap();
        {
            let mut s = entry.session.lock().unwrap();
            let sol = s.solve(0.005, 1e-8).unwrap();
            assert!(sol.report.doublings >= 1, "premise: this solve grows the panel");
            reg.note_query(&entry, &s);
            // The charge is exactly session + deduped snapshot retention,
            // and the stale snapshot genuinely retains something.
            let retained = entry.snapshot().retained_bytes(&s);
            assert!(retained > 0, "stale snapshot must retain the pre-growth panel");
            assert_eq!(
                entry.bytes.load(Ordering::Relaxed),
                s.approx_bytes() + retained,
            );
        }
        assert!(reg.touch(hot).is_some(), "grown model survives its own recharge");
        assert!(reg.touch(cold).is_none(), "growth pressure evicted the colder model");
        assert_eq!(reg.evicted.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn frozen_counters_flow_through_stats_and_listing() {
        let reg = Registry::new(DEFAULT_BYTE_BUDGET);
        let id = register_one(&reg, 128, 16, 8);
        let entry = reg.touch(id).unwrap();
        {
            let mut s = entry.session.lock().unwrap();
            s.solve(0.5, 1e-8).unwrap();
            entry.publish(&mut s).unwrap();
            reg.note_query(&entry, &s);
        }
        // An uncached nu through the frozen lane off the snapshot handle
        // — no session lock, counted as a query but not a cache hit.
        let snap = entry.snapshot();
        match snap.solve_frozen(0.9, 1e-8, None).unwrap().unwrap() {
            crate::solvers::adaptive::FrozenOutcome::Solved(sol) => {
                assert!(sol.report.converged);
                reg.note_frozen_solve(&entry);
            }
            crate::solvers::adaptive::FrozenOutcome::NeedsGrowth { reason, .. } => {
                panic!("larger nu must serve frozen: {reason}")
            }
        }
        reg.note_frozen_fallback(&entry);
        assert_eq!(reg.queries.load(Ordering::Relaxed), 2);
        assert_eq!(entry.snap_hits.load(Ordering::Relaxed), 0);
        let stats = reg.stats_json();
        assert_eq!(stats.get("frozen_solves").unwrap().as_usize(), Some(1));
        assert_eq!(stats.get("frozen_fallbacks").unwrap().as_usize(), Some(1));
        let listing = reg.models_json();
        let m = &listing.as_arr().unwrap()[0];
        assert_eq!(m.get("frozen_solves").unwrap().as_usize(), Some(1));
        assert_eq!(m.get("frozen_fallbacks").unwrap().as_usize(), Some(1));
        assert!(m.get("generation").unwrap().as_usize().unwrap() >= 1);
    }

    #[test]
    fn single_over_budget_model_is_admitted() {
        let reg = Registry::new(1); // absurdly small budget
        let id = register_one(&reg, 64, 8, 4);
        assert!(reg.touch(id).is_some(), "lone model must not evict itself");
        assert_eq!(reg.len(), 1);
        // A second registration makes the first the victim.
        let id2 = register_one(&reg, 64, 8, 5);
        assert_eq!(reg.len(), 1);
        assert!(reg.touch(id).is_none());
        assert!(reg.touch(id2).is_some());
    }

    #[test]
    fn listing_and_stats_shapes() {
        let reg = Registry::new(DEFAULT_BYTE_BUDGET);
        register_one(&reg, 64, 8, 6);
        register_one(&reg, 64, 8, 7);
        let listing = reg.models_json();
        let arr = listing.as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        assert!(arr[0].get("model").unwrap().as_usize().unwrap() <
                arr[1].get("model").unwrap().as_usize().unwrap());
        assert_eq!(arr[0].get("sketch").unwrap().as_str(), Some("gaussian"));
        let stats = reg.stats_json();
        assert_eq!(stats.get("models").unwrap().as_usize(), Some(2));
        assert_eq!(stats.get("registered").unwrap().as_usize(), Some(2));
    }

    #[test]
    fn ids_are_never_reused_after_eviction() {
        let reg = Registry::new(DEFAULT_BYTE_BUDGET);
        let a = register_one(&reg, 64, 8, 1);
        reg.evict(a, false);
        let b = register_one(&reg, 64, 8, 2);
        assert!(b > a, "model ids must stay monotonic");
    }

    // ---- durability ----

    use crate::persist::{DurabilityPolicy, Store};

    fn durable_dir(tag: &str) -> std::path::PathBuf {
        use std::sync::atomic::AtomicUsize;
        static COUNTER: AtomicUsize = AtomicUsize::new(0);
        let dir = std::env::temp_dir().join(format!(
            "effdim-registry-{tag}-{}-{}",
            std::process::id(),
            COUNTER.fetch_add(1, Ordering::Relaxed)
        ));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn durable_registry(dir: &std::path::Path) -> Registry {
        let store = Store::open(dir, DurabilityPolicy::Strict).unwrap();
        Registry::with_store(DEFAULT_BYTE_BUDGET, Arc::new(store))
    }

    /// Regression for the evict data-loss bug: a *lazy* append leaves the
    /// delta rows in the session's pending buffer, and evict used to drop
    /// the entry — pending rows and all. With a store attached, evict
    /// spills: the snapshot path flushes the pending delta first, and a
    /// later touch reloads the model bitwise-equal to a never-spilled twin.
    #[test]
    fn evict_spills_pending_lazy_appends_and_reload_restores_them() {
        use crate::solvers::session::AppendRefresh;
        let _serial = crate::persist::tests_serial();
        let dir = durable_dir("lazy-spill");
        let reg = durable_registry(&dir);
        let id = register_one(&reg, 96, 12, 5);
        let twin_reg = Registry::new(DEFAULT_BYTE_BUDGET);
        let twin_id = register_one(&twin_reg, 96, 12, 5);
        for (r, i) in [(&reg, id), (&twin_reg, twin_id)] {
            let ds = synthetic::exponential_decay(8, 12, 11);
            let entry = r.touch(i).unwrap();
            let mut s = entry.session.lock().unwrap();
            s.append(ds.a, ds.b, AppendRefresh::Lazy).unwrap();
            r.note_append(&entry, &s);
        }
        // Spill while the delta still sits in the pending buffer.
        assert!(reg.evict(id, false));
        let entry = reg.touch(id).expect("spilled model reloads on demand");
        let x = {
            let mut s = entry.session.lock().unwrap();
            assert_eq!(s.n(), 96 + 8, "pending lazy rows survive the spill");
            s.solve(0.5, 1e-9).unwrap().x
        };
        let twin_x = {
            let entry = twin_reg.touch(twin_id).unwrap();
            let mut s = entry.session.lock().unwrap();
            s.solve(0.5, 1e-9).unwrap().x
        };
        let (xb, tb): (Vec<u64>, Vec<u64>) = (
            x.iter().map(|v| v.to_bits()).collect(),
            twin_x.iter().map(|v| v.to_bits()).collect(),
        );
        assert_eq!(xb, tb, "reloaded model must match the never-spilled twin bitwise");
        // Purge really deletes: no transparent reload afterwards.
        assert!(reg.evict(id, true));
        assert!(reg.touch(id).is_none(), "purged model must not reload");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn recover_restores_models_under_their_original_ids() {
        let _serial = crate::persist::tests_serial();
        let dir = durable_dir("recover");
        let (a, b) = {
            let reg = durable_registry(&dir);
            let a = register_one(&reg, 64, 8, 1);
            let b = register_one(&reg, 64, 8, 2);
            reg.persist_all(None).unwrap();
            (a, b)
        };
        let reg = durable_registry(&dir);
        assert_eq!(reg.recover().unwrap(), 2);
        assert!(reg.touch(a).is_some(), "model {a} recovered");
        assert!(reg.touch(b).is_some(), "model {b} recovered");
        let c = register_one(&reg, 64, 8, 3);
        assert!(c > b, "next_id must advance past recovered ids");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn dirty_models_tracks_unsnapshotted_solves() {
        let _serial = crate::persist::tests_serial();
        let dir = durable_dir("dirty");
        let reg = durable_registry(&dir);
        let id = register_one(&reg, 64, 8, 9);
        assert_eq!(reg.dirty_models(), 0, "registration snapshots the fresh model");
        let entry = reg.touch(id).unwrap();
        {
            let mut s = entry.session.lock().unwrap();
            s.solve(0.5, 1e-8).unwrap();
            reg.note_query(&entry, &s);
        }
        assert_eq!(reg.dirty_models(), 1, "a solve moves the epoch past the snapshot");
        reg.persist_all(Some(id)).unwrap();
        assert_eq!(reg.dirty_models(), 0, "snapshot catches the epoch back up");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
