//! L3 coordinator: the solver-as-a-service layer.
//!
//! The paper's contribution is an algorithm, so L3 is a *thin but real*
//! service around it (per DESIGN.md §2): a job queue + worker pool that
//! runs ridge solves and regularization paths, a model registry that
//! keeps per-problem sketch/factorization state hot across requests, a
//! metrics registry, and a TCP server speaking line-delimited JSON. The
//! event loop, process topology, and metrics live in Rust; solves call
//! into the solver stack and (optionally) the PJRT runtime for the AOT
//! hot path.
//!
//! * [`job`] — job specifications (workload x solver x stop rule) and the
//!   job state machine.
//! * [`registry`] — the model registry: register a problem once, then
//!   serve warm-started solves / paths / predictions from cached
//!   [`crate::solvers::session::ModelSession`] state, bounded by an LRU
//!   byte budget.
//! * [`scheduler`] — worker pool with a bounded queue, backpressure, and
//!   bounded terminal-state retention.
//! * [`metrics`] — process-wide counters and latency aggregates.
//! * [`protocol`] — wire encoding of requests/responses.
//! * [`server`] — `std::net` TCP front end (thread per connection).
//!
//! The wire protocol is documented command by command in **`PROTOCOL.md`**
//! at the repository root, rendered into rustdoc as [`protocol_doc`].

pub mod job;
pub mod metrics;
pub mod protocol;
pub mod registry;
pub mod scheduler;
pub mod server;

/// Rendered copy of the repository's `PROTOCOL.md` — the complete wire
/// protocol reference (every command with request/response examples,
/// error shapes, and backpressure/retention semantics).
#[doc = include_str!("../../../PROTOCOL.md")]
pub mod protocol_doc {}

pub use job::{JobId, JobSpec, JobState, Workload};
pub use registry::{ModelId, Registry};
pub use scheduler::Scheduler;
pub use server::Server;
