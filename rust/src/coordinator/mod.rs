//! L3 coordinator: the solver-as-a-service layer.
//!
//! The paper's contribution is an algorithm, so L3 is a *thin but real*
//! service around it (per DESIGN.md §2): a job queue + worker pool that
//! runs ridge solves and regularization paths, a metrics registry, and a
//! TCP server speaking line-delimited JSON. The event loop, process
//! topology, and metrics live in Rust; solves call into the solver stack
//! and (optionally) the PJRT runtime for the AOT hot path.
//!
//! * [`job`] — job specifications (workload x solver x stop rule) and the
//!   job state machine.
//! * [`scheduler`] — worker pool with a bounded queue and backpressure.
//! * [`metrics`] — process-wide counters and latency aggregates.
//! * [`protocol`] — wire encoding of requests/responses.
//! * [`server`] — `std::net` TCP front end (thread per connection).

pub mod job;
pub mod metrics;
pub mod protocol;
pub mod scheduler;
pub mod server;

pub use job::{JobId, JobSpec, JobState, Workload};
pub use scheduler::Scheduler;
pub use server::Server;
