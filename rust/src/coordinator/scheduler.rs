//! Worker-pool scheduler with a bounded queue and backpressure.
//!
//! Invariants (exercised by the property tests in `rust/tests/`):
//! * every accepted job reaches exactly one terminal state;
//! * job ids are unique and monotonically increasing;
//! * at most `workers` jobs run concurrently;
//! * `submit` returns `QueueFull` instead of blocking when the backlog
//!   reaches `queue_cap` (backpressure, never unbounded memory).

use super::job::{self, JobId, JobSpec, JobState};
use super::metrics::Metrics;
use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Submission error.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SubmitError {
    QueueFull,
    ShuttingDown,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::QueueFull => write!(f, "queue full"),
            SubmitError::ShuttingDown => write!(f, "scheduler shutting down"),
        }
    }
}

struct Inner {
    queue: Mutex<VecDeque<(JobId, JobSpec)>>,
    states: Mutex<HashMap<JobId, JobState>>,
    /// Signals workers (new job / shutdown) and waiters (state change).
    cv: Condvar,
    state_cv: Condvar,
    shutdown: AtomicBool,
    next_id: AtomicU64,
    queue_cap: usize,
    pub metrics: Metrics,
}

/// The scheduler handle (cheaply clonable via `Arc`).
pub struct Scheduler {
    inner: Arc<Inner>,
    workers: Vec<JoinHandle<()>>,
}

impl Scheduler {
    /// Start a scheduler with `workers` threads and a queue bound.
    pub fn start(workers: usize, queue_cap: usize) -> Self {
        assert!(workers >= 1);
        let inner = Arc::new(Inner {
            queue: Mutex::new(VecDeque::new()),
            states: Mutex::new(HashMap::new()),
            cv: Condvar::new(),
            state_cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
            next_id: AtomicU64::new(1),
            queue_cap,
            metrics: Metrics::new(),
        });
        let handles = (0..workers)
            .map(|i| {
                let inner = Arc::clone(&inner);
                std::thread::Builder::new()
                    .name(format!("effdim-worker-{i}"))
                    .spawn(move || worker_loop(&inner))
                    .expect("spawn worker")
            })
            .collect();
        Self { inner, workers: handles }
    }

    /// Submit a job; returns its id, or backpressure.
    pub fn submit(&self, spec: JobSpec) -> Result<JobId, SubmitError> {
        if self.inner.shutdown.load(Ordering::SeqCst) {
            return Err(SubmitError::ShuttingDown);
        }
        let mut queue = self.inner.queue.lock().unwrap();
        if queue.len() >= self.inner.queue_cap {
            self.inner.metrics.rejected.fetch_add(1, Ordering::Relaxed);
            return Err(SubmitError::QueueFull);
        }
        let id = self.inner.next_id.fetch_add(1, Ordering::SeqCst);
        self.inner.states.lock().unwrap().insert(id, JobState::Queued);
        queue.push_back((id, spec));
        self.inner.metrics.submitted.fetch_add(1, Ordering::Relaxed);
        drop(queue);
        self.inner.cv.notify_one();
        Ok(id)
    }

    /// Snapshot of a job's state (`None` for unknown ids).
    pub fn status(&self, id: JobId) -> Option<JobState> {
        self.inner.states.lock().unwrap().get(&id).cloned()
    }

    /// Block until the job is terminal (or `timeout` elapses). Returns the
    /// final state if it terminated in time.
    pub fn wait(&self, id: JobId, timeout: Duration) -> Option<JobState> {
        let deadline = Instant::now() + timeout;
        let mut states = self.inner.states.lock().unwrap();
        loop {
            match states.get(&id) {
                None => return None,
                Some(s) if s.is_terminal() => return Some(s.clone()),
                Some(_) => {
                    let now = Instant::now();
                    if now >= deadline {
                        return states.get(&id).cloned();
                    }
                    let (guard, _) = self
                        .inner
                        .state_cv
                        .wait_timeout(states, deadline - now)
                        .unwrap();
                    states = guard;
                }
            }
        }
    }

    /// Number of queued (not yet running) jobs.
    pub fn backlog(&self) -> usize {
        self.inner.queue.lock().unwrap().len()
    }

    /// Process-wide metrics handle.
    pub fn metrics(&self) -> &Metrics {
        &self.inner.metrics
    }

    /// Stop accepting jobs, finish the backlog, join the workers.
    pub fn shutdown(mut self) {
        self.inner.shutdown.store(true, Ordering::SeqCst);
        self.inner.cv.notify_all();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Scheduler {
    fn drop(&mut self) {
        self.inner.shutdown.store(true, Ordering::SeqCst);
        self.inner.cv.notify_all();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(inner: &Inner) {
    loop {
        let next = {
            let mut queue = inner.queue.lock().unwrap();
            loop {
                if let Some(item) = queue.pop_front() {
                    break Some(item);
                }
                if inner.shutdown.load(Ordering::SeqCst) {
                    break None;
                }
                queue = inner.cv.wait(queue).unwrap();
            }
        };
        let Some((id, spec)) = next else { return };

        {
            let mut states = inner.states.lock().unwrap();
            states.insert(id, JobState::Running);
        }
        inner.state_cv.notify_all();

        let start = Instant::now();
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| job::execute(&spec)));
        let elapsed = start.elapsed().as_secs_f64();

        let state = match result {
            Ok(Ok(outcome)) => {
                inner.metrics.completed.fetch_add(1, Ordering::Relaxed);
                inner.metrics.record_solve_time(elapsed);
                JobState::Done(Box::new(outcome))
            }
            Ok(Err(msg)) => {
                inner.metrics.failed.fetch_add(1, Ordering::Relaxed);
                JobState::Failed(msg)
            }
            Err(panic) => {
                inner.metrics.failed.fetch_add(1, Ordering::Relaxed);
                let msg = panic
                    .downcast_ref::<&str>()
                    .map(|s| s.to_string())
                    .or_else(|| panic.downcast_ref::<String>().cloned())
                    .unwrap_or_else(|| "worker panicked".into());
                JobState::Failed(format!("panic: {msg}"))
            }
        };
        inner.states.lock().unwrap().insert(id, state);
        inner.state_cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::job::Workload;
    use crate::solvers::api::SolverSpec;

    fn quick_spec(seed: u64) -> JobSpec {
        JobSpec {
            workload: Workload::Synthetic { profile: "exp".into(), n: 64, d: 8, seed },
            nu: 1.0,
            solver: SolverSpec::Cg,
            eps: 1e-6,
            seed,
            path_nus: Vec::new(),
            threads: None,
        }
    }

    #[test]
    fn submit_run_wait_roundtrip() {
        let s = Scheduler::start(2, 16);
        let id = s.submit(quick_spec(1)).unwrap();
        let state = s.wait(id, Duration::from_secs(30)).expect("job known");
        match state {
            JobState::Done(out) => assert!(out.report.converged),
            other => panic!("unexpected state {other:?}"),
        }
        s.shutdown();
    }

    #[test]
    fn ids_unique_and_increasing() {
        let s = Scheduler::start(1, 64);
        let ids: Vec<JobId> = (0..8).map(|i| s.submit(quick_spec(i)).unwrap()).collect();
        for w in ids.windows(2) {
            assert!(w[1] > w[0]);
        }
        s.shutdown();
    }

    #[test]
    fn backpressure_kicks_in() {
        // One worker + cap 1: the third rapid submit must be rejected
        // (one running, one queued).
        let s = Scheduler::start(1, 1);
        let _a = s.submit(quick_spec(1)).unwrap();
        let mut rejected = false;
        for i in 0..50 {
            match s.submit(quick_spec(i + 2)) {
                Err(SubmitError::QueueFull) => {
                    rejected = true;
                    break;
                }
                Ok(_) => {}
                Err(e) => panic!("unexpected {e}"),
            }
        }
        assert!(rejected, "queue should have filled");
        s.shutdown();
    }

    #[test]
    fn failed_job_reports_error() {
        let s = Scheduler::start(1, 8);
        let mut spec = quick_spec(1);
        spec.workload = Workload::Synthetic { profile: "nope".into(), n: 64, d: 8, seed: 1 };
        let id = s.submit(spec).unwrap();
        let state = s.wait(id, Duration::from_secs(10)).unwrap();
        assert!(matches!(state, JobState::Failed(ref m) if m.contains("unknown workload")));
        s.shutdown();
    }

    #[test]
    fn unknown_id_is_none() {
        let s = Scheduler::start(1, 8);
        assert!(s.status(999).is_none());
        assert!(s.wait(999, Duration::from_millis(10)).is_none());
        s.shutdown();
    }

    #[test]
    fn all_jobs_reach_terminal_state() {
        let s = Scheduler::start(3, 64);
        let ids: Vec<JobId> = (0..12).map(|i| s.submit(quick_spec(i)).unwrap()).collect();
        for id in &ids {
            let state = s.wait(*id, Duration::from_secs(60)).unwrap();
            assert!(state.is_terminal(), "job {id} not terminal");
        }
        let m = s.metrics();
        assert_eq!(m.submitted.load(Ordering::Relaxed), 12);
        assert_eq!(m.completed.load(Ordering::Relaxed), 12);
        s.shutdown();
    }
}
