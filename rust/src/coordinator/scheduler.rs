//! Worker-pool scheduler with a bounded queue and backpressure.
//!
//! Invariants (exercised by the property tests in `rust/tests/`):
//! * every accepted job reaches exactly one terminal state;
//! * job ids are unique and monotonically increasing;
//! * at most `workers` jobs run concurrently;
//! * `submit` returns `QueueFull` instead of blocking when the backlog
//!   reaches `queue_cap` (backpressure, never unbounded memory);
//! * terminal job states are retained for at most
//!   [`DEFAULT_TERMINAL_RETENTION`] jobs (oldest-first eviction; jobs
//!   with a client blocked in `wait` are exempt until the waiter has
//!   observed the result), so a long-lived server's state map cannot
//!   grow without bound — clients that fetch results promptly never
//!   notice; a `status`/`result` for an evicted id reports
//!   `unknown job`.

use super::job::{self, JobId, JobSpec, JobState};
use super::metrics::Metrics;
use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Submission error.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SubmitError {
    /// The backlog is at `queue_cap`; back off and resubmit.
    QueueFull,
    /// The scheduler is shutting down and accepts no new jobs.
    ShuttingDown,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::QueueFull => write!(f, "queue full"),
            SubmitError::ShuttingDown => write!(f, "scheduler shutting down"),
        }
    }
}

/// How many terminal (done/failed) job states a scheduler retains by
/// default before evicting the oldest. Results are a fetch-once protocol:
/// clients `wait`/`result` shortly after submitting, so the window only
/// needs to cover bursts, not history.
pub const DEFAULT_TERMINAL_RETENTION: usize = 1024;

/// Job-state map plus the FIFO of terminal ids that bounds it.
struct StateStore {
    states: HashMap<JobId, JobState>,
    /// Terminal ids in completion order; drained oldest-first once the
    /// retention cap is exceeded.
    terminal_order: VecDeque<JobId>,
    /// Jobs a client is currently blocked in [`Scheduler::wait`] on,
    /// with waiter counts — exempt from retention eviction so a result
    /// cannot vanish between its completion notification and the
    /// waiter's wake-up. Bounded by the number of concurrent waiters
    /// (connections), so the retained map stays
    /// `retention + active waiters` at worst.
    active_waits: HashMap<JobId, usize>,
}

impl StateStore {
    /// Record a terminal state and evict the oldest terminal entries
    /// beyond `retention`, skipping ids with active waiters.
    /// Queued/running entries are never evicted.
    fn insert_terminal(&mut self, id: JobId, state: JobState, retention: usize) {
        debug_assert!(state.is_terminal());
        self.states.insert(id, state);
        self.terminal_order.push_back(id);
        let mut excess = self.terminal_order.len().saturating_sub(retention);
        // Common case: the oldest terminals have no waiter — pop them
        // without touching the rest of the deque.
        while excess > 0 {
            let front_evictable = self
                .terminal_order
                .front()
                .is_some_and(|old| !self.active_waits.contains_key(old));
            if !front_evictable {
                break;
            }
            let old = self.terminal_order.pop_front().unwrap();
            self.states.remove(&old);
            excess -= 1;
        }
        // Rare case: the front is actively waited on — scan past it.
        if excess > 0 {
            let mut kept = VecDeque::with_capacity(self.terminal_order.len());
            for old in std::mem::take(&mut self.terminal_order) {
                if excess > 0 && !self.active_waits.contains_key(&old) {
                    self.states.remove(&old);
                    excess -= 1;
                } else {
                    kept.push_back(old);
                }
            }
            self.terminal_order = kept;
        }
    }
}

struct Inner {
    queue: Mutex<VecDeque<(JobId, JobSpec)>>,
    states: Mutex<StateStore>,
    /// Signals workers (new job / shutdown) and waiters (state change).
    cv: Condvar,
    state_cv: Condvar,
    shutdown: AtomicBool,
    next_id: AtomicU64,
    queue_cap: usize,
    terminal_retention: usize,
    /// Process-wide counters (shared with the public handle).
    pub metrics: Metrics,
}

/// The scheduler handle (cheaply clonable via `Arc`).
pub struct Scheduler {
    inner: Arc<Inner>,
    workers: Vec<JoinHandle<()>>,
}

impl Scheduler {
    /// Start a scheduler with `workers` threads and a queue bound
    /// (terminal states retained per [`DEFAULT_TERMINAL_RETENTION`]).
    pub fn start(workers: usize, queue_cap: usize) -> Self {
        Self::start_with_retention(workers, queue_cap, DEFAULT_TERMINAL_RETENTION)
    }

    /// [`Scheduler::start`] with an explicit terminal-state retention cap
    /// (must be >= 1; tests use small values to exercise eviction).
    pub fn start_with_retention(
        workers: usize,
        queue_cap: usize,
        terminal_retention: usize,
    ) -> Self {
        assert!(workers >= 1);
        assert!(terminal_retention >= 1);
        let inner = Arc::new(Inner {
            queue: Mutex::new(VecDeque::new()),
            states: Mutex::new(StateStore {
                states: HashMap::new(),
                terminal_order: VecDeque::new(),
                active_waits: HashMap::new(),
            }),
            cv: Condvar::new(),
            state_cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
            next_id: AtomicU64::new(1),
            queue_cap,
            terminal_retention,
            metrics: Metrics::new(),
        });
        let handles = (0..workers)
            .map(|i| {
                let inner = Arc::clone(&inner);
                std::thread::Builder::new()
                    .name(format!("effdim-worker-{i}"))
                    .spawn(move || worker_loop(&inner))
                    .expect("spawn worker")
            })
            .collect();
        Self { inner, workers: handles }
    }

    /// Submit a job; returns its id, or backpressure.
    pub fn submit(&self, spec: JobSpec) -> Result<JobId, SubmitError> {
        if self.inner.shutdown.load(Ordering::SeqCst) {
            return Err(SubmitError::ShuttingDown);
        }
        let mut queue = self.inner.queue.lock().unwrap();
        if queue.len() >= self.inner.queue_cap {
            self.inner.metrics.rejected.fetch_add(1, Ordering::Relaxed);
            return Err(SubmitError::QueueFull);
        }
        let id = self.inner.next_id.fetch_add(1, Ordering::SeqCst);
        self.inner.states.lock().unwrap().states.insert(id, JobState::Queued);
        queue.push_back((id, spec));
        self.inner.metrics.submitted.fetch_add(1, Ordering::Relaxed);
        drop(queue);
        self.inner.cv.notify_one();
        Ok(id)
    }

    /// Snapshot of a job's state (`None` for unknown ids — never
    /// submitted, or terminal long enough ago that retention evicted it).
    pub fn status(&self, id: JobId) -> Option<JobState> {
        self.inner.states.lock().unwrap().states.get(&id).cloned()
    }

    /// Number of job states currently retained (all lifecycle stages).
    pub fn retained_states(&self) -> usize {
        self.inner.states.lock().unwrap().states.len()
    }

    /// Block until the job is terminal (or `timeout` elapses). Returns the
    /// final state if it terminated in time. While a waiter is blocked
    /// here, the job's terminal state is exempt from retention eviction,
    /// so completing during the wait always hands the result over.
    pub fn wait(&self, id: JobId, timeout: Duration) -> Option<JobState> {
        let deadline = Instant::now() + timeout;
        let mut store = self.inner.states.lock().unwrap();
        if !store.states.contains_key(&id) {
            return None;
        }
        *store.active_waits.entry(id).or_insert(0) += 1;
        let result = loop {
            match store.states.get(&id) {
                None => break None,
                Some(s) if s.is_terminal() => break Some(s.clone()),
                Some(_) => {
                    let now = Instant::now();
                    if now >= deadline {
                        break store.states.get(&id).cloned();
                    }
                    let (guard, _) = self
                        .inner
                        .state_cv
                        .wait_timeout(store, deadline - now)
                        .unwrap();
                    store = guard;
                }
            }
        };
        match store.active_waits.get_mut(&id) {
            Some(c) if *c > 1 => *c -= 1,
            _ => {
                store.active_waits.remove(&id);
            }
        }
        result
    }

    /// Number of queued (not yet running) jobs.
    pub fn backlog(&self) -> usize {
        self.inner.queue.lock().unwrap().len()
    }

    /// Process-wide metrics handle.
    pub fn metrics(&self) -> &Metrics {
        &self.inner.metrics
    }

    /// Stop accepting jobs, finish the backlog, join the workers.
    pub fn shutdown(mut self) {
        self.inner.shutdown.store(true, Ordering::SeqCst);
        self.inner.cv.notify_all();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Scheduler {
    fn drop(&mut self) {
        self.inner.shutdown.store(true, Ordering::SeqCst);
        self.inner.cv.notify_all();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(inner: &Inner) {
    loop {
        let next = {
            let mut queue = inner.queue.lock().unwrap();
            loop {
                if let Some(item) = queue.pop_front() {
                    break Some(item);
                }
                if inner.shutdown.load(Ordering::SeqCst) {
                    break None;
                }
                queue = inner.cv.wait(queue).unwrap();
            }
        };
        let Some((id, spec)) = next else { return };

        {
            let mut store = inner.states.lock().unwrap();
            store.states.insert(id, JobState::Running);
        }
        inner.state_cv.notify_all();

        let start = Instant::now();
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| job::execute(&spec)));
        let elapsed = start.elapsed().as_secs_f64();

        let state = match result {
            Ok(Ok(outcome)) => JobState::Done(Box::new(outcome)),
            Ok(Err(msg)) => JobState::Failed(msg),
            Err(panic) => JobState::Failed(panic_message(&*panic)),
        };
        let done = matches!(state, JobState::Done(_));
        {
            // State insert and counter increments share one critical
            // section (insert first): a waiter that observed the terminal
            // state can rely on the counters being updated, and a metrics
            // poller that observed `completed + failed == N` can rely on
            // all N terminal states having been inserted — the retention
            // tests poll exactly this.
            let mut store = inner.states.lock().unwrap();
            store.insert_terminal(id, state, inner.terminal_retention);
            if done {
                inner.metrics.completed.fetch_add(1, Ordering::Relaxed);
                inner.metrics.record_solve_time(elapsed);
            } else {
                inner.metrics.failed.fetch_add(1, Ordering::Relaxed);
            }
        }
        inner.state_cv.notify_all();
    }
}

/// Human-readable payload of a caught panic (shared by the worker loop
/// and the server's synchronous registry path). Delegates to the
/// solver-layer formatter so wire responses and job states agree on the
/// `panic: ...` shape.
pub(crate) fn panic_message(panic: &(dyn std::any::Any + Send)) -> String {
    crate::solvers::error::panic_message(panic)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::job::Workload;
    use crate::solvers::api::SolverSpec;

    fn quick_spec(seed: u64) -> JobSpec {
        JobSpec {
            workload: Workload::Synthetic { profile: "exp".into(), n: 64, d: 8, seed },
            nu: 1.0,
            solver: SolverSpec::Cg,
            eps: 1e-6,
            seed,
            path_nus: Vec::new(),
            threads: None,
        }
    }

    #[test]
    fn submit_run_wait_roundtrip() {
        let s = Scheduler::start(2, 16);
        let id = s.submit(quick_spec(1)).unwrap();
        let state = s.wait(id, Duration::from_secs(30)).expect("job known");
        match state {
            JobState::Done(out) => assert!(out.report.converged),
            other => panic!("unexpected state {other:?}"),
        }
        s.shutdown();
    }

    #[test]
    fn ids_unique_and_increasing() {
        let s = Scheduler::start(1, 64);
        let ids: Vec<JobId> = (0..8).map(|i| s.submit(quick_spec(i)).unwrap()).collect();
        for w in ids.windows(2) {
            assert!(w[1] > w[0]);
        }
        s.shutdown();
    }

    #[test]
    fn backpressure_kicks_in() {
        // One worker + cap 1: the third rapid submit must be rejected
        // (one running, one queued).
        let s = Scheduler::start(1, 1);
        let _a = s.submit(quick_spec(1)).unwrap();
        let mut rejected = false;
        for i in 0..50 {
            match s.submit(quick_spec(i + 2)) {
                Err(SubmitError::QueueFull) => {
                    rejected = true;
                    break;
                }
                Ok(_) => {}
                Err(e) => panic!("unexpected {e}"),
            }
        }
        assert!(rejected, "queue should have filled");
        s.shutdown();
    }

    #[test]
    fn failed_job_reports_error() {
        let s = Scheduler::start(1, 8);
        let mut spec = quick_spec(1);
        spec.workload = Workload::Synthetic { profile: "nope".into(), n: 64, d: 8, seed: 1 };
        let id = s.submit(spec).unwrap();
        let state = s.wait(id, Duration::from_secs(10)).unwrap();
        assert!(matches!(state, JobState::Failed(ref m) if m.contains("unknown workload")));
        s.shutdown();
    }

    #[test]
    fn unknown_id_is_none() {
        let s = Scheduler::start(1, 8);
        assert!(s.status(999).is_none());
        assert!(s.wait(999, Duration::from_millis(10)).is_none());
        s.shutdown();
    }

    #[test]
    fn terminal_states_are_bounded_by_retention() {
        // Retention 4: after 12 sequential jobs only the 4 newest
        // terminal states survive; older ids answer like unknown jobs.
        // Drain by polling metrics rather than waiting on individual ids:
        // with retention this small, a result can be evicted before a
        // per-id wait gets scheduled (results are fetch-once — see the
        // module docs).
        let s = Scheduler::start_with_retention(1, 64, 4);
        let ids: Vec<JobId> = (0..12).map(|i| s.submit(quick_spec(i)).unwrap()).collect();
        let deadline = Instant::now() + Duration::from_secs(60);
        let m = s.metrics();
        while (m.completed.load(Ordering::Relaxed) + m.failed.load(Ordering::Relaxed)) < 12 {
            assert!(Instant::now() < deadline, "jobs did not finish in time");
            std::thread::sleep(Duration::from_millis(5));
        }
        assert!(s.retained_states() <= 4, "retained {}", s.retained_states());
        assert!(s.status(ids[0]).is_none(), "oldest terminal state must be evicted");
        // One worker completes in FIFO order, so the newest id is the most
        // recent terminal and must still be retained.
        let newest = *ids.last().unwrap();
        assert!(matches!(s.status(newest), Some(JobState::Done(_))));
        s.shutdown();
    }

    #[test]
    fn waiting_client_never_loses_result_to_retention() {
        // Retention 1 and a pile of later jobs: the job a client is
        // blocked in wait() on must survive eviction until observed.
        let s = Arc::new(Scheduler::start_with_retention(1, 64, 1));
        let a = s.submit(quick_spec(1)).unwrap();
        let (tx, rx) = std::sync::mpsc::channel();
        let s2 = Arc::clone(&s);
        let waiter = std::thread::spawn(move || {
            tx.send(()).unwrap();
            s2.wait(a, Duration::from_secs(60))
        });
        // Give the waiter time to register, then flood the retention
        // window with newer terminals.
        rx.recv().unwrap();
        std::thread::sleep(Duration::from_millis(100));
        for i in 0..6 {
            s.submit(quick_spec(i + 2)).unwrap();
        }
        let state = waiter
            .join()
            .unwrap()
            .expect("a waited-on result must not be evicted out from under the waiter");
        assert!(state.is_terminal());
        drop(s); // last handle: Drop shuts the workers down
    }

    #[test]
    fn all_jobs_reach_terminal_state() {
        let s = Scheduler::start(3, 64);
        let ids: Vec<JobId> = (0..12).map(|i| s.submit(quick_spec(i)).unwrap()).collect();
        for id in &ids {
            let state = s.wait(*id, Duration::from_secs(60)).unwrap();
            assert!(state.is_terminal(), "job {id} not terminal");
        }
        let m = s.metrics();
        assert_eq!(m.submitted.load(Ordering::Relaxed), 12);
        assert_eq!(m.completed.load(Ordering::Relaxed), 12);
        s.shutdown();
    }
}
