//! End-to-end dense/CSR operand equivalence: the same problem stored two
//! ways must produce the same answers through every layer — solvers
//! (registry-wide), the sketch engine's growth path, the dual reduction,
//! and the parallel CSR kernels (bitwise across thread counts).

use effdim::data::synthetic;
use effdim::linalg::threads::with_threads;
use effdim::linalg::{Matrix, Operand};
use effdim::rng::Xoshiro256;
use effdim::sketch::engine::SketchEngine;
use effdim::sketch::SketchKind;
use effdim::solvers::dual::{solve_direct, DualRidge};
use effdim::solvers::{direct, registry, RidgeProblem, Solver as _, SolverSpec, StopRule};

const KINDS: [SketchKind; 3] = [SketchKind::Gaussian, SketchKind::Srht, SketchKind::Sparse];

/// The same sparse problem stored densely and as CSR (identical entries
/// and observations — see `synthetic::sparse_gaussian`'s twin contract).
fn twin_problems(
    n: usize,
    d: usize,
    density: f64,
    nu: f64,
    seed: u64,
) -> (RidgeProblem, RidgeProblem) {
    let dense = synthetic::sparse_gaussian_dense(n, d, density, seed);
    let sparse = synthetic::sparse_gaussian(n, d, density, seed);
    assert_eq!(dense.b, sparse.b, "twin contract broken");
    (
        RidgeProblem::new(dense.a, dense.b, nu),
        RidgeProblem::new(sparse.a, sparse.b, nu),
    )
}

#[test]
fn gradient_hessian_and_error_agree_between_variants() {
    let (pd, ps) = twin_problems(96, 12, 0.15, 0.8, 1);
    let x: Vec<f64> = (0..12).map(|i| (i as f64 * 0.37).sin()).collect();
    let v: Vec<f64> = (0..12).map(|i| (i as f64 * 0.21).cos()).collect();
    let (gd, gs) = (pd.gradient(&x), ps.gradient(&x));
    let (hd, hs) = (pd.hessian_vec(&v), ps.hessian_vec(&v));
    for i in 0..12 {
        assert!((gd[i] - gs[i]).abs() < 1e-12, "gradient coord {i}");
        assert!((hd[i] - hs[i]).abs() < 1e-12, "hessian coord {i}");
    }
    let x_ref = vec![0.0; 12];
    let ed = pd.prediction_error(&x, &x_ref);
    let es = ps.prediction_error(&x, &x_ref);
    assert!((ed - es).abs() < 1e-10 * ed.max(1.0));
    assert!((pd.objective(&x) - ps.objective(&x)).abs() < 1e-10);
}

#[test]
fn registry_solutions_agree_between_dense_and_csr_twins() {
    // nu = 1.0 keeps the problem well-conditioned so both runs track the
    // same decision path; the final iterates then differ only by kernel
    // rounding (dense fused gradient vs CSR two-pass), far below 1e-10.
    let (pd, ps) = twin_problems(128, 16, 0.2, 1.0, 2);
    let x_star = direct::solve(&pd);
    let x_star_s = direct::solve(&ps);
    for i in 0..16 {
        assert!(
            (x_star[i] - x_star_s[i]).abs() < 1e-10,
            "direct twin drift at {i}: {} vs {}",
            x_star[i],
            x_star_s[i]
        );
    }
    let x0 = vec![0.0; 16];
    for spec in registry() {
        if matches!(spec, SolverSpec::DualAdaptive { .. }) {
            continue; // needs d >= n; covered by the dual twin test below
        }
        // The SAME oracle for both runs: any difference then comes from
        // the operand kernels alone, not from two direct solves.
        let stop = StopRule::TrueError { x_star: x_star.clone(), eps: 1e-10 };
        let sd = spec.build(7).solve(&pd, &x0, &stop);
        let ss = spec.build(7).solve(&ps, &x0, &stop);
        assert!(sd.report.converged, "{spec} dense did not converge");
        assert!(ss.report.converged, "{spec} csr did not converge");
        for i in 0..16 {
            assert!(
                (sd.x[i] - ss.x[i]).abs() < 1e-10,
                "{spec} coord {i}: dense {} vs csr {}",
                sd.x[i],
                ss.x[i]
            );
        }
    }
}

#[test]
fn dual_reduction_agrees_between_dense_and_csr_twins() {
    // Wide (d >= n) sparse problem through the dual path, both storages.
    let base_dense = synthetic::sparse_gaussian_dense(64, 16, 0.25, 3);
    let base_sparse = synthetic::sparse_gaussian(64, 16, 0.25, 3);
    let a_dense = base_dense.a.transpose(); // 16 x 64
    let a_sparse = base_sparse.a.transpose();
    let b = base_dense.b[..16].to_vec();
    let nu = 0.9;

    let xd = solve_direct(&a_dense, &b, nu);
    let xs = solve_direct(&a_sparse, &b, nu);
    for i in 0..64 {
        assert!((xd[i] - xs[i]).abs() < 1e-10, "dual direct coord {i}");
    }

    let cfg = effdim::AdaptiveConfig::new(SketchKind::Sparse);
    let run = |a: Operand| {
        let dr = DualRidge::new(a, b.clone(), nu);
        let stop = effdim::solvers::dual::dual_stop(&dr.dual, 1e-10);
        dr.solve_adaptive(&cfg, &stop, 11)
    };
    let sol_d = run(a_dense);
    let sol_s = run(a_sparse);
    assert!(sol_d.report.converged && sol_s.report.converged);
    for i in 0..64 {
        assert!(
            (sol_d.x[i] - sol_s.x[i]).abs() < 1e-8,
            "dual adaptive coord {i}: {} vs {}",
            sol_d.x[i],
            sol_s.x[i]
        );
    }
}

#[test]
fn sketch_engine_growth_is_prefix_consistent_on_csr() {
    // The engine contract (append-only unnormalized rows) must hold with
    // a CSR operand exactly as it does with a dense one, for all three
    // families, across several growth steps.
    let ds = synthetic::sparse_gaussian(48, 9, 0.2, 4);
    for kind in KINDS {
        let mut rng = Xoshiro256::seed_from_u64(5);
        let mut engine = SketchEngine::new(kind, 2, &ds.a, &mut rng);
        let mut snapshots = vec![engine.sa_unnormalized().clone()];
        for &m in &[5usize, 12, 30] {
            engine.grow(m, &ds.a, &mut rng).unwrap();
            snapshots.push(engine.sa_unnormalized().clone());
        }
        for w in snapshots.windows(2) {
            let (small, big) = (&w[0], &w[1]);
            for i in 0..small.rows() {
                assert_eq!(small.row(i), big.row(i), "{kind} prefix row {i} drifted on CSR");
            }
        }
        // And the CSR-grown sketch matches the dense-operand twin.
        let dense = ds.a.dense().into_owned();
        let mut rng2 = Xoshiro256::seed_from_u64(5);
        let mut engine_d = SketchEngine::new(kind, 2, &dense, &mut rng2);
        for &m in &[5usize, 12, 30] {
            engine_d.grow(m, &dense, &mut rng2).unwrap();
        }
        assert!(
            engine_d.sa_unnormalized().max_abs_diff(engine.sa_unnormalized()) < 1e-10,
            "{kind} dense/CSR growth drift"
        );
    }
}

#[test]
fn csr_kernels_are_bitwise_thread_invariant_at_scale() {
    // Above the parallel thresholds (2 * nnz >= 4e5), every CSR kernel
    // must agree bitwise across thread counts — matvec by row
    // partitioning, matvec_t/gram by the fixed-chunk reduction.
    let ds = synthetic::sparse_gaussian(2048, 192, 0.6, 6);
    let csr = ds.a.as_csr().unwrap();
    assert!(2 * csr.nnz() >= 400_000, "premise: above the parallel threshold");
    let x: Vec<f64> = (0..192).map(|i| (i as f64 * 0.17).sin()).collect();
    let xt: Vec<f64> = (0..2048).map(|i| (i as f64 * 0.013).cos()).collect();
    let mut grng = Xoshiro256::seed_from_u64(7);
    let g = Matrix::from_fn(6, 2048, |_, _| grng.next_gaussian());
    let mv1 = with_threads(1, || csr.matvec(&x));
    let mt1 = with_threads(1, || csr.matvec_t(&xt));
    let gram1 = with_threads(1, || csr.gram());
    let lm1 = with_threads(1, || csr.left_mul(&g));
    for t in [2, 5, 8] {
        assert_eq!(with_threads(t, || csr.matvec(&x)), mv1, "matvec t={t}");
        assert_eq!(with_threads(t, || csr.matvec_t(&xt)), mt1, "matvec_t t={t}");
        assert_eq!(with_threads(t, || csr.gram()), gram1, "gram t={t}");
        assert_eq!(with_threads(t, || csr.left_mul(&g)), lm1, "left_mul t={t}");
    }
    // The dense Gram now shares the fixed-chunk reduction: bitwise too.
    let dense = ds.a.dense().into_owned();
    let dgram1 = with_threads(1, || dense.gram());
    for t in [2, 5, 8] {
        assert_eq!(with_threads(t, || dense.gram()), dgram1, "dense gram t={t}");
    }
}

#[test]
fn csr_solution_agrees_with_direct_on_triplet_input() {
    // Triplet text -> CSR problem -> registry solve, against the dense
    // reconstruction of the same file.
    let ds = synthetic::sparse_gaussian(64, 8, 0.3, 8);
    let csr = ds.a.as_csr().unwrap();
    let text = effdim::data::format_triplet_problem(csr, &ds.b);
    let (parsed, b) = effdim::data::parse_triplet_problem(&text).unwrap();
    assert_eq!(&parsed, csr);
    let p = RidgeProblem::new(parsed, b, 0.7);
    let x_star = direct::solve(&p);
    let stop = StopRule::TrueError { x_star: x_star.clone(), eps: 1e-10 };
    let sol = "adaptive-sparse".parse::<SolverSpec>().unwrap().build(9).solve(
        &p,
        &vec![0.0; 8],
        &stop,
    );
    assert!(sol.report.converged);
    assert!(sol.report.final_rel_error.unwrap() <= 1e-10);
}
