//! Counting-allocator proof that the iterative hot loops are
//! allocation-free in the steady state (the PR's workspace-buffer
//! contract): extra iterations of `cg` / `pcg` / `ihs` cost zero heap
//! allocations, and an accepted `AdaptiveSolver::step` after warmup
//! allocates nothing.
//!
//! Methodology: a `#[global_allocator]` wrapper counts every
//! alloc/realloc. For the plain-function solvers we run the same solve at
//! two iteration caps under a never-satisfied `GradientNorm { tol: 0.0 }`
//! rule — setup allocations are identical, so the count difference is
//! exactly the per-iteration allocation rate times the extra iterations.
//! For the adaptive solver we drive `step()` directly after a warmup that
//! sizes every buffer. Problems are kept below the parallel-kernel
//! thresholds and pinned to one thread: above `worth_parallelizing`, the
//! parallel kernels themselves allocate scoped-thread stacks and
//! reduction partials by design (the documented exception in lib.rs) —
//! what this test pins is that the *solver-level* loops allocate nothing.
//!
//! Everything runs inside ONE `#[test]` so no concurrent test pollutes
//! the global counter.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(l)
    }
    unsafe fn alloc_zeroed(&self, l: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(l)
    }
    unsafe fn realloc(&self, p: *mut u8, l: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(p, l, new_size)
    }
    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn allocs_during<R>(f: impl FnOnce() -> R) -> (u64, R) {
    let before = ALLOCS.load(Ordering::Relaxed);
    let r = f();
    (ALLOCS.load(Ordering::Relaxed) - before, r)
}

#[test]
fn iterative_hot_loops_do_not_allocate_per_iteration() {
    use effdim::data::synthetic;
    use effdim::linalg::threads::with_threads;
    use effdim::sketch::SketchKind;
    use effdim::solvers::adaptive::{AdaptiveConfig, AdaptiveSolver, AdaptiveVariant};
    use effdim::solvers::cg::{self, CgConfig};
    use effdim::solvers::ihs::{self, IhsConfig};
    use effdim::solvers::pcg::{self, PcgConfig};
    use effdim::solvers::{RidgeProblem, StopRule};

    // Small dense problem: every kernel stays below the parallel
    // threshold, so the loops are pure serial arithmetic.
    let ds = synthetic::exponential_decay(64, 16, 1);
    let p = RidgeProblem::new(ds.a.clone(), ds.b.clone(), 1.0);
    let x0 = vec![0.0; 16];
    // Never satisfied: the solvers run exactly to their iteration cap
    // (or to an exact-zero residual, which costs no allocation either).
    let stop = StopRule::GradientNorm { tol: 0.0 };

    with_threads(1, || {
        // --- cg: extra iterations must cost zero allocations ---
        let cg_run = |iters: usize| {
            allocs_during(|| cg::solve(&p, &x0, &CgConfig { max_iters: iters }, &stop)).0
        };
        cg_run(5); // warm any lazy runtime state
        let (lo, hi) = (cg_run(5), cg_run(25));
        assert_eq!(hi, lo, "cg allocates per iteration: {lo} allocs at 5 iters, {hi} at 25");

        // --- pcg ---
        let pcg_run = |iters: usize| {
            let mut cfg = PcgConfig::new(SketchKind::Srht, 0.5);
            cfg.max_iters = iters;
            allocs_during(|| pcg::solve(&p, &x0, &cfg, &stop, 3)).0
        };
        pcg_run(5);
        let (lo, hi) = (pcg_run(5), pcg_run(25));
        assert_eq!(hi, lo, "pcg allocates per iteration: {lo} at 5 iters, {hi} at 25");

        // --- fixed-size ihs (gradient variant) ---
        let ihs_run = |iters: usize| {
            let mut cfg = IhsConfig::gaussian(16, 0.15);
            cfg.momentum = false;
            cfg.max_iters = iters;
            allocs_during(|| ihs::solve(&p, &x0, &cfg, &stop, 4)).0
        };
        ihs_run(5);
        let (lo, hi) = (ihs_run(5), ihs_run(25));
        assert_eq!(hi, lo, "ihs allocates per iteration: {lo} at 5 iters, {hi} at 25");

        // --- adaptive: steady-state step() allocates nothing ---
        // m_initial = n puts the sketch at its cap from the start, so the
        // gradient candidate is always accepted (no growth rounds can
        // enter the measured window) and GradientOnly skips the Polyak
        // candidate: each step is exactly the hot path under test.
        let mut cfg = AdaptiveConfig::new(SketchKind::Gaussian);
        cfg.variant = AdaptiveVariant::GradientOnly;
        cfg.m_initial = 64;
        let mut solver = AdaptiveSolver::new(&p, &x0, cfg, stop.clone(), 5);
        for _ in 0..3 {
            solver.step(); // warmup: sizes every candidate/scratch buffer
        }
        let (steady, _) = allocs_during(|| {
            for _ in 0..10 {
                solver.step();
            }
        });
        assert_eq!(
            steady, 0,
            "adaptive step() allocated {steady} times across 10 steady-state steps"
        );
    });
}
