//! PJRT runtime conformance: the AOT-compiled fused-gradient artifact must
//! agree with the native implementation to f32 precision, and the
//! XLA-backed adaptive solve must converge.
//!
//! These tests need `make artifacts` (shape n=4096, d=256); they skip with
//! a notice when artifacts are absent so `cargo test` works on a fresh
//! checkout.

#![cfg(feature = "xla-runtime")]

use effdim::data::synthetic;
use effdim::runtime::{GradientOracle, PjrtRuntime, DEFAULT_ARTIFACTS_DIR};
use effdim::sketch::SketchKind;
use effdim::solvers::adaptive::{AdaptiveConfig, AdaptiveSolver};
use effdim::solvers::{direct, RidgeProblem, StopRule};

fn runtime_or_skip() -> Option<PjrtRuntime> {
    match PjrtRuntime::load(DEFAULT_ARTIFACTS_DIR) {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("[skip] artifacts unavailable: {e}");
            None
        }
    }
}

fn problem_for(rt: &PjrtRuntime) -> RidgeProblem {
    let (n, d) = (rt.manifest.n, rt.manifest.d);
    let ds = synthetic::cifar_like(n, d, 99);
    RidgeProblem::new(ds.a, ds.b, 1.0)
}

#[test]
fn manifest_lists_gradient_artifact() {
    let Some(rt) = runtime_or_skip() else { return };
    let name = format!("gradient_n{}_d{}", rt.manifest.n, rt.manifest.d);
    assert!(rt.has(&name), "manifest missing {name}");
    assert!(!rt.manifest.m_list.is_empty());
}

#[test]
fn xla_gradient_matches_native_to_f32() {
    let Some(rt) = runtime_or_skip() else { return };
    let problem = problem_for(&rt);
    let oracle = rt.gradient_oracle(&problem).expect("oracle");
    assert_eq!(oracle.backend(), "pjrt-xla");

    for seed in 0..3u64 {
        let mut rng = effdim::rng::Xoshiro256::seed_from_u64(seed);
        let x: Vec<f64> = (0..problem.d()).map(|_| rng.next_gaussian()).collect();
        let g_native = problem.gradient(&x);
        let g_xla = oracle.gradient(&x);
        let scale = g_native.iter().map(|v| v.abs()).fold(1e-30, f64::max);
        for i in 0..problem.d() {
            let rel = (g_native[i] - g_xla[i]).abs() / scale;
            assert!(rel < 1e-4, "seed {seed} coord {i}: native {} xla {}", g_native[i], g_xla[i]);
        }
    }
}

#[test]
fn adaptive_solve_with_xla_gradient_converges() {
    let Some(rt) = runtime_or_skip() else { return };
    let problem = problem_for(&rt);
    let oracle = rt.gradient_oracle(&problem).expect("oracle");
    let x_star = direct::solve(&problem);
    // f32 artifact: target a tolerance above the mixed-precision floor.
    let stop = StopRule::TrueError { x_star, eps: 1e-5 };
    let cfg = AdaptiveConfig::new(SketchKind::Srht);
    let mut solver = AdaptiveSolver::new(&problem, &vec![0.0; problem.d()], cfg, stop, 7);
    solver.set_gradient_fn(|x| oracle.gradient(x));
    let sol = solver.run();
    assert!(
        sol.report.converged,
        "XLA-backed adaptive solve failed: rel {:?}",
        sol.report.final_rel_error
    );
}

#[test]
fn shape_mismatch_is_rejected() {
    let Some(rt) = runtime_or_skip() else { return };
    let ds = synthetic::exponential_decay(128, 16, 1);
    let p = RidgeProblem::new(ds.a, ds.b, 0.5);
    assert!(rt.gradient_oracle(&p).is_err(), "mismatched shapes must be rejected");
}
