//! Property-based tests (hand-rolled generator loop — the offline build
//! has no proptest). Each property is checked over many randomized cases
//! drawn from a seeded RNG; failures print the case for reproduction.

use effdim::coordinator::job::{JobSpec, Workload};
use effdim::solvers::SolverSpec;
use effdim::coordinator::scheduler::Scheduler;
use effdim::linalg::cholesky::Cholesky;
use effdim::linalg::{norm2, Matrix};
use effdim::rng::Xoshiro256;
use effdim::sketch::{self, SketchKind};
use effdim::solvers::woodbury::WoodburyCache;
use effdim::solvers::{direct, RidgeProblem};
use std::time::Duration;

/// Run `cases` randomized checks of `property`, feeding it a fresh RNG.
fn check_property(name: &str, cases: usize, mut property: impl FnMut(u64, &mut Xoshiro256)) {
    for case in 0..cases as u64 {
        let mut rng = Xoshiro256::seed_from_u64(0xbeef ^ case.wrapping_mul(0x9E3779B97F4A7C15));
        // A panic inside `property` fails the test; include the case id.
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            property(case, &mut rng);
        }));
        if let Err(e) = result {
            panic!("property '{name}' failed at case {case}: {e:?}");
        }
    }
}

fn random_dims(rng: &mut Xoshiro256) -> (usize, usize) {
    let d = 1usize << (2 + rng.next_below(4) as usize); // 4..32
    let n = d << (1 + rng.next_below(3) as usize); // 2d..8d
    (n, d)
}

// ---------------------------------------------------------------------------
// Linalg / sketch invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_gemm_matches_naive() {
    check_property("gemm == naive", 30, |_case, rng| {
        let m = 1 + rng.next_below(40) as usize;
        let k = 1 + rng.next_below(40) as usize;
        let n = 1 + rng.next_below(40) as usize;
        let a = Matrix::from_fn(m, k, |_, _| rng.next_gaussian());
        let b = Matrix::from_fn(k, n, |_, _| rng.next_gaussian());
        let c = a.matmul(&b);
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0;
                for p in 0..k {
                    s += a.get(i, p) * b.get(p, j);
                }
                assert!((c.get(i, j) - s).abs() < 1e-9);
            }
        }
    });
}

#[test]
fn prop_cholesky_solve_inverts() {
    check_property("cholesky solve", 25, |_case, rng| {
        let d = 1 + rng.next_below(24) as usize;
        let g = Matrix::from_fn(d + 2, d, |_, _| rng.next_gaussian());
        let mut spd = g.gram();
        spd.add_diag(0.1 + rng.next_f64());
        let chol = Cholesky::factor(&spd).unwrap();
        let x0: Vec<f64> = (0..d).map(|_| rng.next_gaussian()).collect();
        let b = spd.matvec(&x0);
        let x = chol.solve(&b);
        for i in 0..d {
            assert!((x[i] - x0[i]).abs() < 1e-7, "coord {i}");
        }
    });
}

#[test]
fn prop_sketches_preserve_norms_on_average() {
    // E ||S x||^2 = ||x||^2 for every family; check the empirical mean
    // over sketches stays within a loose band.
    check_property("sketch isometry", 6, |case, rng| {
        let kind = match case % 3 {
            0 => SketchKind::Gaussian,
            1 => SketchKind::Srht,
            _ => SketchKind::Sparse,
        };
        let n = 64;
        let x: Vec<f64> = (0..n).map(|_| rng.next_gaussian()).collect();
        let xm = Matrix::from_vec(n, 1, x.clone());
        let x2 = norm2(&x).powi(2);
        let trials = 60;
        let mut acc = 0.0;
        for _ in 0..trials {
            let s = sketch::sample(kind, 32, n, rng);
            let sx = s.apply(&xm);
            acc += sx.as_slice().iter().map(|v| v * v).sum::<f64>();
        }
        let mean = acc / trials as f64;
        assert!(
            (mean - x2).abs() < 0.35 * x2,
            "{kind}: mean {mean} vs {x2}"
        );
    });
}

#[test]
fn prop_woodbury_inverts_hs_any_shape() {
    check_property("woodbury inverse", 30, |_case, rng| {
        let d = 2 + rng.next_below(20) as usize;
        let m = 1 + rng.next_below(2 * d as u64) as usize;
        let sa = Matrix::from_fn(m, d, |_, _| rng.next_gaussian() * 0.6);
        let nu = 0.2 + rng.next_f64();
        let cache = WoodburyCache::new(sa.clone(), nu).unwrap();
        let g: Vec<f64> = (0..d).map(|_| rng.next_gaussian()).collect();
        let z = cache.apply_inverse(&g);
        let hz = cache.h_s().matvec(&z);
        for i in 0..d {
            assert!((hz[i] - g[i]).abs() < 1e-7, "m={m} d={d} coord {i}");
        }
    });
}

// ---------------------------------------------------------------------------
// Solver invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_direct_solution_is_stationary() {
    check_property("direct stationarity", 15, |case, rng| {
        let (n, d) = random_dims(rng);
        let ds = effdim::data::synthetic::exponential_decay(n, d, 0x5eed + case);
        let nu = 10f64.powf(rng.next_f64() * 4.0 - 2.0); // 1e-2..1e2
        let p = RidgeProblem::new(ds.a, ds.b, nu);
        let x = direct::solve(&p);
        let g = p.gradient(&x);
        let scale = norm2(&p.atb).max(1.0);
        assert!(norm2(&g) / scale < 1e-8, "n={n} d={d} nu={nu}");
    });
}

#[test]
fn prop_adaptive_m_monotone_and_bounded() {
    use effdim::solvers::adaptive::{self, AdaptiveConfig};
    use effdim::solvers::StopRule;
    check_property("adaptive m monotone", 8, |case, rng| {
        let (n, d) = random_dims(rng);
        let ds = effdim::data::synthetic::exponential_decay(n, d, 0xfeed + case);
        let nu = 10f64.powf(rng.next_f64() * 2.0 - 1.0);
        let p = RidgeProblem::new(ds.a.clone(), ds.b.clone(), nu);
        let x_star = direct::solve(&p);
        let kind = if case % 2 == 0 { SketchKind::Gaussian } else { SketchKind::Srht };
        let cfg = AdaptiveConfig::new(kind);
        let stop = StopRule::TrueError { x_star, eps: 1e-8 };
        let sol = adaptive::solve(&p, &vec![0.0; d], &cfg, &stop, 0xabc + case).unwrap();
        assert!(sol.report.converged, "n={n} d={d} nu={nu} {kind}");
        for w in sol.report.m_trace.windows(2) {
            assert!(w[1] >= w[0], "m must never shrink");
        }
        let cap = effdim::sketch::srht::next_pow2(n);
        assert!(sol.report.peak_m <= cap);
    });
}

// ---------------------------------------------------------------------------
// Coordinator invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_scheduler_never_loses_or_duplicates_jobs() {
    // Submit a randomized batch under random worker counts; every accepted
    // job must reach exactly one terminal state and ids must be unique.
    check_property("scheduler conservation", 4, |case, rng| {
        let workers = 1 + rng.next_below(3) as usize;
        let s = Scheduler::start(workers, 128);
        let batch = 4 + rng.next_below(8) as usize;
        let mut ids = Vec::new();
        for i in 0..batch {
            let spec = JobSpec {
                workload: Workload::Synthetic {
                    profile: if i % 4 == 3 { "nope".into() } else { "exp".into() },
                    n: 64,
                    d: 8,
                    seed: case * 100 + i as u64,
                },
                nu: 1.0,
                solver: SolverSpec::Cg,
                eps: 1e-6,
                seed: i as u64,
                path_nus: Vec::new(),
                threads: None,
            };
            ids.push(s.submit(spec).unwrap());
        }
        // Unique, increasing ids.
        let mut sorted = ids.clone();
        sorted.dedup();
        assert_eq!(sorted.len(), ids.len());
        // All terminal; invalid profiles fail, the rest complete.
        let mut done = 0;
        let mut failed = 0;
        for (i, id) in ids.iter().enumerate() {
            match s.wait(*id, Duration::from_secs(60)).unwrap() {
                effdim::coordinator::job::JobState::Done(_) => done += 1,
                effdim::coordinator::job::JobState::Failed(_) => {
                    assert_eq!(i % 4, 3, "only the bad profile may fail");
                    failed += 1;
                }
                other => panic!("non-terminal state {other:?}"),
            }
        }
        assert_eq!(done + failed, batch);
        let m = s.metrics();
        use std::sync::atomic::Ordering;
        assert_eq!(m.submitted.load(Ordering::Relaxed) as usize, batch);
        assert_eq!(
            (m.completed.load(Ordering::Relaxed) + m.failed.load(Ordering::Relaxed)) as usize,
            batch
        );
        s.shutdown();
    });
}

#[test]
fn prop_json_roundtrip() {
    use effdim::util::json::{parse, Json};
    check_property("json roundtrip", 40, |_case, rng| {
        // Random nested value.
        fn gen(rng: &mut Xoshiro256, depth: usize) -> Json {
            match if depth == 0 { rng.next_below(4) } else { rng.next_below(6) } {
                0 => Json::Null,
                1 => Json::Bool(rng.next_u64() & 1 == 0),
                2 => Json::Num((rng.next_gaussian() * 100.0 * 64.0).round() / 64.0),
                3 => Json::Str(format!("s{}-\"esc\"\n", rng.next_below(1000))),
                4 => Json::Arr((0..rng.next_below(4)).map(|_| gen(rng, depth - 1)).collect()),
                _ => Json::Obj(
                    (0..rng.next_below(4))
                        .map(|i| (format!("k{i}"), gen(rng, depth - 1)))
                        .collect(),
                ),
            }
        }
        let v = gen(rng, 3);
        let text = v.to_string();
        let back = parse(&text).unwrap_or_else(|e| panic!("parse {text:?}: {e}"));
        assert_eq!(back, v, "{text}");
    });
}

// ---------------------------------------------------------------------------
// Durability codecs: WAL records and checksummed snapshots
// ---------------------------------------------------------------------------

/// Unique scratch path for a property case's WAL/snapshot file.
fn persist_scratch(tag: &str, case: u64) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("effdim-prop-{}-{tag}", std::process::id()));
    let _ = std::fs::create_dir_all(&dir);
    dir.join(format!("case-{case}"))
}

/// Random small delta block (dense or CSR, the two wire storage kinds).
fn random_delta(rng: &mut Xoshiro256) -> (effdim::Operand, Vec<f64>) {
    use effdim::linalg::sparse::CsrMatrix;
    let rows = 1 + rng.next_below(6) as usize;
    let cols = 1 + rng.next_below(8) as usize;
    let b: Vec<f64> = (0..rows).map(|_| rng.next_gaussian()).collect();
    let a = if rng.next_u64() & 1 == 0 {
        effdim::Operand::Dense(Matrix::from_fn(rows, cols, |_, _| rng.next_gaussian()))
    } else {
        let mut trips = Vec::new();
        for i in 0..rows {
            for j in 0..cols {
                if rng.next_f64() < 0.4 {
                    trips.push((i, j, rng.next_gaussian()));
                }
            }
        }
        effdim::Operand::Sparse(CsrMatrix::from_triplets(rows, cols, &trips))
    };
    (a, b)
}

#[test]
fn prop_wal_record_roundtrip() {
    use effdim::persist::wal::{decode_append, encode_append};
    check_property("wal record roundtrip", 40, |_case, rng| {
        let (a, b) = random_delta(rng);
        let eager = rng.next_u64() & 1 == 0;
        let rec = decode_append(&encode_append(&a, &b, eager)).expect("roundtrip decodes");
        assert_eq!(rec.eager, eager);
        assert_eq!(rec.b.len(), b.len());
        for (x, y) in rec.b.iter().zip(&b) {
            assert_eq!(x.to_bits(), y.to_bits(), "b must survive bitwise");
        }
        assert_eq!(rec.a.rows(), a.rows());
        assert_eq!(rec.a.cols(), a.cols());
        let (da, db) = (rec.a.dense(), a.dense());
        for i in 0..a.rows() {
            for j in 0..a.cols() {
                assert_eq!(
                    da.get(i, j).to_bits(),
                    db.get(i, j).to_bits(),
                    "delta entry ({i},{j}) must survive bitwise"
                );
            }
        }
    });
}

#[test]
fn prop_wal_scan_survives_truncation_at_every_byte_offset() {
    use effdim::persist::wal::{encode_append, scan, Wal};
    use effdim::persist::DurabilityPolicy;
    // Two records; the scan of any prefix must stop at the last whole
    // record before the cut — never error, never return a partial record.
    check_property("wal truncation sweep", 8, |case, rng| {
        let path = persist_scratch("wal-trunc", case);
        let _ = std::fs::remove_file(&path);
        let mut wal = Wal::open(&path, DurabilityPolicy::Off, 0).unwrap();
        let mut boundaries = vec![0u64]; // valid_len after k whole records
        for _ in 0..2 {
            let (a, b) = random_delta(rng);
            wal.append(&encode_append(&a, &b, true)).unwrap();
            boundaries.push(wal.len());
        }
        wal.sync().unwrap();
        drop(wal);
        let full = std::fs::read(&path).unwrap();
        for cut in 0..=full.len() {
            std::fs::write(&path, &full[..cut]).unwrap();
            let s = scan(&path).unwrap_or_else(|e| panic!("cut {cut}: scan errored: {e}"));
            let whole = boundaries.iter().filter(|&&b| b <= cut as u64).count() - 1;
            assert_eq!(s.records.len(), whole, "cut {cut}: whole-record prefix");
            assert_eq!(s.valid_len, boundaries[whole], "cut {cut}: valid_len");
            assert_eq!(
                s.truncated_tail,
                cut as u64 > boundaries[whole],
                "cut {cut}: tail flag"
            );
        }
        let _ = std::fs::remove_file(&path);
    });
}

#[test]
fn prop_wal_scan_stops_at_corrupted_record() {
    use effdim::persist::wal::{encode_append, scan, Wal};
    use effdim::persist::DurabilityPolicy;
    check_property("wal corruption stops scan", 20, |case, rng| {
        let path = persist_scratch("wal-crc", case);
        let _ = std::fs::remove_file(&path);
        let mut wal = Wal::open(&path, DurabilityPolicy::Off, 0).unwrap();
        let (a, b) = random_delta(rng);
        wal.append(&encode_append(&a, &b, true)).unwrap();
        let first_end = wal.len();
        let (a2, b2) = random_delta(rng);
        wal.append(&encode_append(&a2, &b2, false)).unwrap();
        wal.sync().unwrap();
        drop(wal);
        // Flip one byte anywhere in the second record (header or payload).
        let mut bytes = std::fs::read(&path).unwrap();
        let span = bytes.len() - first_end as usize;
        let victim = first_end as usize + rng.next_below(span as u64) as usize;
        bytes[victim] ^= 1 << rng.next_below(8);
        std::fs::write(&path, &bytes).unwrap();
        let s = scan(&path).unwrap();
        // Corrupting the length field can make the header claim a longer
        // record than the file holds (a torn tail); any other flip fails
        // the magic or CRC. Either way: stop at the last good record.
        assert_eq!(s.records.len(), 1, "scan must stop at the corrupted record");
        assert_eq!(s.valid_len, first_end);
        assert!(s.truncated_tail, "the corrupt tail must be flagged");
        let _ = std::fs::remove_file(&path);
    });
}

#[test]
fn prop_snapshot_decode_rejects_any_single_byte_corruption() {
    use effdim::data::synthetic;
    use effdim::persist::snapshot::{decode, encode_session};
    use effdim::sketch::SketchKind;
    use effdim::solvers::session::ModelSession;
    use std::sync::Arc;
    check_property("snapshot corruption rejected", 12, |_case, rng| {
        let (n, d) = random_dims(rng);
        let ds = synthetic::exponential_decay(n, d, rng.next_u64());
        let atb_ref: Vec<f64>;
        let mut sess = ModelSession::new(Arc::new(ds.a), ds.b, SketchKind::Gaussian, 3).unwrap();
        if rng.next_u64() & 1 == 0 {
            sess.solve(0.5, 1e-8).unwrap(); // snapshot a warmed session too
        }
        atb_ref = sess.atb().to_vec();
        let bytes = encode_session("prop", &mut sess).unwrap();

        // Clean decode round-trips the identifying fields bitwise.
        let snap = decode(&bytes).expect("clean snapshot decodes");
        assert_eq!(snap.name, "prop");
        assert_eq!(snap.a.rows(), n);
        assert_eq!(snap.a.cols(), d);
        snap.verify_atb_digest().expect("stored digest matches");
        assert_eq!(snap.atb.len(), atb_ref.len());
        for (x, y) in snap.atb.iter().zip(&atb_ref) {
            assert_eq!(x.to_bits(), y.to_bits(), "atb must survive bitwise");
        }

        // One flipped bit anywhere must fail decode (file CRC), and any
        // truncation must fail decode — never panic, never a wrong model.
        for _ in 0..8 {
            let mut bad = bytes.clone();
            let at = rng.next_below(bad.len() as u64) as usize;
            bad[at] ^= 1 << rng.next_below(8);
            assert!(decode(&bad).is_err(), "flipped byte {at} must be detected");
        }
        for _ in 0..4 {
            let cut = rng.next_below(bytes.len() as u64) as usize;
            assert!(decode(&bytes[..cut]).is_err(), "truncation at {cut} must be detected");
        }
    });
}

#[test]
fn prop_snapshot_generations_are_monotone_and_never_serve_retired_vectors() {
    // Deterministic-interleaving sweep over the RCU serving core: an
    // LCG (seeded per case) schedules reader loads, writer solves,
    // and writer appends in one thread, so every interleaving is exactly
    // reproducible from the case id. Invariants: generations only move
    // forward; every snapshot agrees bitwise with an independently
    // maintained oracle of what its generation was published with; and
    // a snapshot published after an append never serves a vector cached
    // before it (appends retire the whole solution cache), while pinned
    // older handles keep serving their own generation's bits.
    use effdim::coordinator::registry::{Registry, DEFAULT_BYTE_BUDGET};
    use effdim::data::synthetic;
    use effdim::solvers::session::{AppendRefresh, SessionSnapshot};
    use effdim::Operand;
    use std::collections::HashMap;
    use std::sync::Arc;

    const EPS: f64 = 1e-8;
    const NUS: [f64; 4] = [0.2, 0.4, 0.6, 0.8];

    check_property("snapshot interleavings", 8, |case, rng| {
        let d = 4 + rng.next_below(5) as usize;
        let n = d * (3 + rng.next_below(3) as usize);
        let ds = synthetic::exponential_decay(n, d, rng.next_u64());
        let registry = Registry::new(DEFAULT_BYTE_BUDGET);
        let entry = registry
            .register("prop".into(), ds.a, ds.b, SketchKind::Gaussian, 7)
            .unwrap();

        // Oracle state, maintained in lockstep with the writer ops: the
        // exact (nu, eps) -> x bits the cache must hold, rebuilt from
        // each solve's *returned* Solution (not read back through the
        // snapshot, so the comparison is independent), cleared on append.
        let mut live: HashMap<(u64, u64), Vec<u64>> = HashMap::new();
        let mut expected_n = n;
        let mut expected_gen = 1u64; // registration published generation 1
        // log[i] = (generation, n, cache content) of the i-th publish.
        let mut log: Vec<(u64, usize, HashMap<(u64, u64), Vec<u64>>)> =
            vec![(1, n, HashMap::new())];
        let mut pinned: Vec<(Arc<SessionSnapshot>, usize)> = Vec::new();
        let mut last_gen = 0u64;

        let verify = |snap: &SessionSnapshot, (gen, nn, cache): &(u64, usize, HashMap<(u64, u64), Vec<u64>>)| {
            assert_eq!(snap.generation(), *gen);
            assert_eq!(snap.n(), *nn, "case {case}: rows diverged at gen {gen}");
            let keys: Vec<(u64, u64)> = snap.solution_keys();
            assert_eq!(keys.len(), cache.len(), "case {case}: cache size diverged at gen {gen}");
            for key in keys {
                let want = cache.get(&key).unwrap_or_else(|| {
                    panic!("case {case}: gen {gen} serves a retired/foreign vector {key:?}")
                });
                let sol = snap.cached(f64::from_bits(key.0), f64::from_bits(key.1)).unwrap();
                let got: Vec<u64> = sol.x.iter().map(|v| v.to_bits()).collect();
                assert_eq!(&got, want, "case {case}: bits diverged at gen {gen}, key {key:?}");
            }
        };

        let mut lcg: u64 = 0x2545F4914F6CDD1D ^ case;
        for _ in 0..24 {
            lcg = lcg.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            match lcg >> 61 {
                // Reader: load a snapshot, check monotonicity, match it
                // against the oracle log entry for its generation, and
                // sometimes pin it for the end-of-case recheck.
                0..=3 => {
                    let snap = entry.snapshot();
                    let gen = snap.generation();
                    assert!(gen >= last_gen, "case {case}: generation went backwards");
                    last_gen = gen;
                    let idx = log
                        .iter()
                        .position(|(g, _, _)| *g == gen)
                        .unwrap_or_else(|| panic!("case {case}: unpublished generation {gen}"));
                    verify(&snap, &log[idx]);
                    if pinned.len() < 4 {
                        pinned.push((snap, idx));
                    }
                }
                // Writer: solve one of the palette nus and publish.
                4 | 5 => {
                    let nu = NUS[(lcg >> 32) as usize % NUS.len()];
                    let mut session = entry.session.lock().unwrap();
                    let sol = session.solve(nu, EPS).unwrap();
                    entry.publish(&mut session).unwrap();
                    drop(session);
                    live.insert(
                        (nu.to_bits(), EPS.to_bits()),
                        sol.x.iter().map(|v| v.to_bits()).collect(),
                    );
                    expected_gen += 1;
                    log.push((expected_gen, expected_n, live.clone()));
                }
                // Writer: append a couple of random rows (eager or lazy)
                // and publish; the cache retires wholesale.
                _ => {
                    let dn = 1 + ((lcg >> 32) as usize & 1);
                    let delta = Matrix::from_fn(dn, d, |_, _| rng.next_gaussian());
                    let db: Vec<f64> = (0..dn).map(|_| rng.next_gaussian()).collect();
                    let refresh = if (lcg >> 40) & 1 == 0 {
                        AppendRefresh::Eager
                    } else {
                        AppendRefresh::Lazy
                    };
                    let mut session = entry.session.lock().unwrap();
                    session.append(Operand::from(delta), db, refresh).unwrap();
                    entry.publish(&mut session).unwrap();
                    drop(session);
                    live.clear();
                    expected_n += dn;
                    expected_gen += 1;
                    log.push((expected_gen, expected_n, live.clone()));
                }
            }
        }
        // Every pinned handle still answers exactly what its own
        // generation implied, no matter how many retirements followed.
        for (snap, idx) in &pinned {
            verify(snap, &log[*idx]);
        }
    });
}
