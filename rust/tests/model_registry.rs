//! Integration: the model registry's cross-request reuse contract.
//!
//! Pins the PR's acceptance criteria:
//! * a repeat query against a registered model performs **no fresh sketch
//!   application** — `SolveReport::sketch_time_s` is exactly `0.0` on the
//!   second solve at a new `nu` and the cached `m` rows are reused in
//!   full (no doublings, `m` unchanged);
//! * LRU models are evicted under byte-budget pressure and evicted ids
//!   return a clean error;
//! * terminal job states are bounded (the scheduler's `states` map cannot
//!   grow without limit);
//! * a registered model served concurrently from N client threads returns
//!   bitwise-identical solutions.

use effdim::coordinator::registry::Registry;
use effdim::coordinator::server::{Client, Server};
use effdim::data::synthetic;
use effdim::sketch::SketchKind;
use std::sync::atomic::Ordering;
use std::sync::Arc;

fn registry_with_model(n: usize, d: usize, seed: u64) -> (Registry, u64) {
    let reg = Registry::new(usize::MAX);
    let ds = synthetic::exponential_decay(n, d, seed);
    let id = reg
        .register("it".into(), ds.a, ds.b, SketchKind::Gaussian, seed)
        .unwrap()
        .id;
    (reg, id)
}

#[test]
fn repeat_query_pays_zero_sketch_time_and_reuses_cached_rows() {
    let (reg, id) = registry_with_model(512, 64, 1);
    let entry = reg.touch(id).unwrap();
    let mut session = entry.session.lock().unwrap();

    // First query: grows the sketch from m = 1, paying real sketch time.
    let first = session.solve(0.3, 1e-9).unwrap();
    assert!(first.report.converged);
    assert!(first.report.sketch_time_s > 0.0, "first solve must build the sketch");
    let cached_m = session.m();
    assert!(cached_m >= 1);

    // Second query at a different nu (larger => smaller effective
    // dimension, so the cached rows certainly suffice): the reuse
    // contract is zero sketch application and the full cached prefix.
    let second = session.solve(1.0, 1e-9).unwrap();
    assert!(second.report.converged);
    assert_eq!(
        second.report.sketch_time_s, 0.0,
        "repeat query applied a fresh sketch (time bucket nonzero)"
    );
    assert_eq!(second.report.doublings, 0, "repeat query re-grew the sketch");
    assert_eq!(session.m(), cached_m, "cached sketch rows must be reused in full");

    // Third query at a smaller nu may grow further, but never re-applies
    // the existing prefix: m only moves up.
    let third = session.solve(0.05, 1e-9).unwrap();
    assert!(third.report.converged);
    assert!(session.m() >= cached_m);
}

#[test]
fn lru_eviction_under_byte_budget_and_clean_errors() {
    // Measure one model's footprint, then budget for two.
    let probe = Registry::new(usize::MAX);
    let ds = synthetic::exponential_decay(128, 16, 9);
    let bytes = {
        let e = probe.register("p".into(), ds.a, ds.b, SketchKind::Gaussian, 9).unwrap();
        let s = e.session.lock().unwrap();
        s.approx_bytes()
    };

    let reg = Registry::new(bytes * 2 + bytes / 2);
    let mut ids = Vec::new();
    for seed in 0..3u64 {
        let ds = synthetic::exponential_decay(128, 16, seed);
        ids.push(
            reg.register(format!("m{seed}"), ds.a, ds.b, SketchKind::Gaussian, seed)
                .unwrap()
                .id,
        );
    }
    // Three same-size models against a two-model budget: the oldest was
    // evicted at the third registration.
    assert_eq!(reg.len(), 2);
    assert!(reg.touch(ids[0]).is_none(), "LRU model must be gone");
    assert!(reg.touch(ids[1]).is_some() && reg.touch(ids[2]).is_some());
    assert_eq!(reg.evicted.load(Ordering::Relaxed), 1);
    // The error clients see is the standard unknown-model shape.
    let msg = Registry::unknown(ids[0]);
    assert!(msg.contains("unknown model") && msg.contains("re-register"), "{msg}");
}

#[test]
fn terminal_job_states_are_bounded() {
    use effdim::coordinator::job::{JobSpec, Workload};
    use effdim::coordinator::Scheduler;
    use std::time::Duration;

    let s = Scheduler::start_with_retention(2, 64, 8);
    let spec = |seed: u64| JobSpec {
        workload: Workload::Synthetic { profile: "exp".into(), n: 64, d: 8, seed },
        nu: 1.0,
        solver: "cg".parse().unwrap(),
        eps: 1e-6,
        seed,
        path_nus: Vec::new(),
        threads: None,
    };
    let ids: Vec<u64> = (0..32).map(|i| s.submit(spec(i)).unwrap()).collect();
    // Drain via metrics: waiting on individual ids would race with
    // retention evicting already-terminal results (fetch-once protocol).
    let m = s.metrics();
    let deadline = std::time::Instant::now() + Duration::from_secs(60);
    use std::sync::atomic::Ordering as AtomicOrdering;
    while (m.completed.load(AtomicOrdering::Relaxed) + m.failed.load(AtomicOrdering::Relaxed)) < 32
    {
        assert!(std::time::Instant::now() < deadline, "jobs did not finish in time");
        std::thread::sleep(Duration::from_millis(5));
    }
    // No queued/running jobs remain, so the retained map is exactly the
    // bounded terminal window.
    assert!(
        s.retained_states() <= 8,
        "states map leaked: {} entries for 32 jobs at retention 8",
        s.retained_states()
    );
    assert!(s.status(ids[0]).is_none(), "old terminal state must be evicted");
    s.shutdown();
}

#[test]
fn concurrent_clients_get_bitwise_identical_solutions() {
    let (reg, id) = registry_with_model(256, 32, 3);
    let reg = Arc::new(reg);
    let n_threads = 8;
    let results: Vec<Vec<f64>> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..n_threads)
            .map(|_| {
                let reg = Arc::clone(&reg);
                scope.spawn(move || {
                    let entry = reg.touch(id).expect("model registered");
                    let mut session = entry.session.lock().unwrap();
                    let sol = session.solve(0.5, 1e-9).unwrap();
                    reg.note_query(&entry, &session);
                    sol.x
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    for x in &results[1..] {
        assert_eq!(
            x, &results[0],
            "concurrent identical queries must be bitwise-identical"
        );
    }
    // All but the first came from the solution cache.
    let entry = reg.touch(id).unwrap();
    let session = entry.session.lock().unwrap();
    let (queries, hits) = session.query_stats();
    assert_eq!(queries, n_threads as u64);
    assert_eq!(hits, n_threads as u64 - 1);
}

#[test]
fn failed_queries_cannot_poison_a_registered_session() {
    // Robustness regression: a query that fails mid-solve (here: an
    // already-expired wall deadline, which aborts between iterations,
    // and an invalid batch) must leave the registered session exactly
    // as it was — same answers bitwise, same byte accounting, model
    // still registered.
    //
    // Deliberately failpoint-free: tests in this binary run in parallel
    // threads and the failpoint registry is process-global (armed-site
    // tests live in tests/chaos.rs, which serializes on a suite mutex).
    let (reg, id) = registry_with_model(256, 32, 7);
    let entry = reg.touch(id).unwrap();
    let mut session = entry.session.lock().unwrap();

    let baseline = session.solve(0.5, 1e-9).unwrap();
    assert!(baseline.report.converged);
    let bytes = session.approx_bytes();
    let m = session.m();

    // Expired deadline: the cooperative check fails the solve with a
    // structured error and rolls the session back.
    session.set_deadline(Some(std::time::Instant::now() - std::time::Duration::from_millis(1)));
    let err = session.solve(0.05, 1e-12).expect_err("expired deadline must fail the solve");
    assert!(err.contains("deadline"), "{err}");
    session.set_deadline(None);

    // Invalid inputs fail fast, before any state is touched.
    assert!(session.solve(f64::NAN, 1e-9).is_err());
    assert!(session.solve_block(0.5, &[], 1e-9).is_err());

    // Nothing leaked: sketch size and byte footprint are unchanged, the
    // model is still registered, and the original query re-answers
    // bitwise (solution cache intact).
    assert_eq!(session.m(), m, "failed queries changed the cached sketch");
    assert_eq!(session.approx_bytes(), bytes, "failed queries changed the byte footprint");
    let again = session.solve(0.5, 1e-9).unwrap();
    assert_eq!(again.x, baseline.x, "post-failure answer must be bitwise the baseline");
    reg.note_query(&entry, &session);
    drop(session);
    assert!(reg.touch(id).is_some(), "failed queries must not evict the model");
}

#[test]
fn pipelined_tagged_queries_on_one_connection_match_by_id() {
    // Wire-level pipelining (PROTOCOL.md §Concurrency): k tagged
    // requests go out back-to-back on ONE connection before any
    // response is read; the k tagged responses may come back in any
    // completion order and are matched by id. Repeat-nu queries ride
    // the lock-free snapshot path, a fresh-nu query takes the writer
    // path, and a tagged failure stays tagged — all on the same socket.
    let server = Server::bind("127.0.0.1:0", 1).unwrap();
    let addr = server.local_addr();
    let stop = server.stop_handle();
    let handle = std::thread::spawn(move || server.run());
    let mut client = Client::connect(addr).unwrap();

    let reg = client
        .call(r#"{"cmd":"register","profile":"exp","n":128,"d":16,"seed":9,"sketch":"gaussian"}"#)
        .unwrap();
    assert_eq!(reg.get("ok").unwrap().as_bool(), Some(true), "{reg:?}");
    let model = reg.get("model").unwrap().as_usize().unwrap();

    // Warm the cache (and publish the snapshot) with one untagged solve,
    // keeping its solution vector as the bitwise reference.
    let warm = client
        .call(&format!(
            r#"{{"cmd":"query","model":{model},"nu":0.3,"eps":1e-8,"include_x":true}}"#
        ))
        .unwrap();
    assert_eq!(warm.get("ok").unwrap().as_bool(), Some(true), "{warm:?}");
    let reference_x = format!("{:?}", warm.get("result").unwrap().get("x").unwrap());

    // Six interleaved tagged requests, no reads in between: three
    // repeat-nu cache hits, one fresh nu, one ping, one tagged error.
    for line in [
        format!(r#"{{"id":10,"cmd":"query","model":{model},"nu":0.3,"eps":1e-8,"include_x":true}}"#),
        format!(r#"{{"id":11,"cmd":"query","model":{model},"nu":0.3,"eps":1e-8,"include_x":true}}"#),
        format!(r#"{{"id":12,"cmd":"query","model":{model},"nu":0.3,"eps":1e-8,"include_x":true}}"#),
        format!(r#"{{"id":13,"cmd":"query","model":{model},"nu":0.9,"eps":1e-8}}"#),
        r#"{"id":14,"cmd":"ping"}"#.to_string(),
        r#"{"id":15,"cmd":"query","model":424242,"nu":0.5}"#.to_string(),
    ] {
        client.send(&line).unwrap();
    }
    let mut by_id = std::collections::HashMap::new();
    for _ in 0..6 {
        let resp = client.recv().unwrap();
        let id = resp.get("id").expect("pipelined response lost its tag").as_usize().unwrap();
        assert!(by_id.insert(id, resp).is_none(), "duplicate response id");
    }
    assert_eq!(by_id.len(), 6, "every request must be answered exactly once");
    for id in [10usize, 11, 12] {
        let resp = &by_id[&id];
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true), "{resp:?}");
        let x = format!("{:?}", resp.get("result").unwrap().get("x").unwrap());
        assert_eq!(x, reference_x, "pipelined repeat query {id} diverged from the warm solve");
    }
    assert_eq!(by_id[&13].get("ok").unwrap().as_bool(), Some(true), "{:?}", by_id[&13]);
    assert_eq!(by_id[&14].get("ok").unwrap().as_bool(), Some(true));
    let failed = &by_id[&15];
    assert_eq!(failed.get("ok").unwrap().as_bool(), Some(false));
    assert!(failed.get("error").unwrap().as_str().unwrap().contains("unknown model"));

    // A malformed id is a strict-decode failure: the error comes back
    // untagged and in-order (the id itself cannot be trusted).
    client.send(r#"{"id":1.5,"cmd":"ping"}"#).unwrap();
    let bad = client.recv().unwrap();
    assert!(bad.get("id").is_none(), "malformed-id error must be untagged: {bad:?}");
    assert_eq!(bad.get("ok").unwrap().as_bool(), Some(false));
    assert!(bad.get("error").unwrap().as_str().unwrap().contains("request id"), "{bad:?}");

    // The connection survives all of it for ordinary untagged traffic.
    let pong = client.call(r#"{"cmd":"ping"}"#).unwrap();
    assert_eq!(pong.get("ok").unwrap().as_bool(), Some(true));

    stop.store(true, Ordering::SeqCst);
    handle.join().unwrap();
}

#[test]
fn registry_reuse_over_tcp_end_to_end() {
    // Full wire-level pass: register, query twice (second at a new nu
    // reports zero sketch time), evict, query again -> clean error.
    let server = Server::bind("127.0.0.1:0", 1).unwrap();
    let addr = server.local_addr();
    let stop = server.stop_handle();
    let handle = std::thread::spawn(move || server.run());
    let mut client = Client::connect(addr).unwrap();

    let reg = client
        .call(r#"{"cmd":"register","profile":"exp","n":256,"d":32,"seed":5,"sketch":"gaussian"}"#)
        .unwrap();
    assert_eq!(reg.get("ok").unwrap().as_bool(), Some(true), "{reg:?}");
    let model = reg.get("model").unwrap().as_usize().unwrap();

    let q1 = client
        .call(&format!(r#"{{"cmd":"query","model":{model},"nu":0.3,"eps":1e-8}}"#))
        .unwrap();
    assert_eq!(q1.get("ok").unwrap().as_bool(), Some(true), "{q1:?}");
    assert_eq!(
        q1.get("result").unwrap().get("converged").unwrap().as_bool(),
        Some(true)
    );
    let m1 = q1.get("m").unwrap().as_usize().unwrap();

    let q2 = client
        .call(&format!(r#"{{"cmd":"query","model":{model},"nu":1.0,"eps":1e-8}}"#))
        .unwrap();
    let r2 = q2.get("result").unwrap();
    assert_eq!(r2.get("sketch_time_s").unwrap().as_f64(), Some(0.0));
    assert_eq!(r2.get("doublings").unwrap().as_usize(), Some(0));
    assert_eq!(q2.get("m").unwrap().as_usize(), Some(m1));

    client.call(&format!(r#"{{"cmd":"evict","model":{model}}}"#)).unwrap();
    let gone = client
        .call(&format!(r#"{{"cmd":"query","model":{model},"nu":1.0}}"#))
        .unwrap();
    assert_eq!(gone.get("ok").unwrap().as_bool(), Some(false));
    assert!(gone.get("error").unwrap().as_str().unwrap().contains("unknown model"));

    stop.store(true, Ordering::SeqCst);
    handle.join().unwrap();
}
