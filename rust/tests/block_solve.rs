//! Integration: the block multi-RHS solve path's acceptance contract.
//!
//! Pins the PR's acceptance criteria:
//! * `solve_block(nu, B, eps)` over `k` right-hand sides agrees
//!   column-wise with `k` independent `solve_rhs` calls (dense and CSR
//!   operands, Gaussian and SRHT sketches);
//! * a block query resumed against an already-grown session applies
//!   **zero** fresh sketch (`sketch_time_s == 0.0`, no doublings, `m`
//!   unchanged);
//! * per-column convergence tracking retires easy columns early while
//!   hard columns keep iterating;
//! * the corrected session byte accounting feeds the registry's LRU
//!   budget: query-driven state growth (warm start + cached solutions +
//!   sketch state) triggers eviction at the right totals.

use effdim::coordinator::registry::Registry;
use effdim::data::synthetic;
use effdim::sketch::SketchKind;
use effdim::solvers::session::ModelSession;
use effdim::Operand;
use std::sync::Arc;

fn rhs_batch(n: usize, k: usize) -> Vec<Vec<f64>> {
    (0..k)
        .map(|j| {
            (0..n)
                .map(|i| ((i as f64 + 1.0) * (j as f64 * 0.83 + 0.41)).sin())
                .collect()
        })
        .collect()
}

fn assert_columns_agree(block: &[effdim::solvers::Solution], looped: &[Vec<f64>], tag: &str) {
    assert_eq!(block.len(), looped.len());
    for (j, (sol, lone)) in block.iter().zip(looped).enumerate() {
        assert!(sol.report.converged, "{tag}: column {j} did not converge");
        let scale = lone.iter().fold(1.0f64, |m, v| m.max(v.abs()));
        for (i, (&xb, &xl)) in sol.x.iter().zip(lone).enumerate() {
            assert!(
                (xb - xl).abs() <= 1e-10 * scale,
                "{tag}: column {j} coord {i}: block {xb} vs looped {xl}"
            );
        }
    }
}

#[test]
fn block_agrees_with_looped_solves_dense_operand() {
    for kind in [SketchKind::Gaussian, SketchKind::Srht] {
        let ds = synthetic::exponential_decay(256, 32, 3);
        let bs = rhs_batch(256, 5);
        let mk = || {
            ModelSession::new(Arc::new(ds.a.clone()), ds.b.clone(), kind, 11).unwrap()
        };
        let mut s_block = mk();
        let sols = s_block.solve_block(0.5, &bs, 1e-12).unwrap();
        let mut s_loop = mk();
        let looped: Vec<Vec<f64>> = bs
            .iter()
            .map(|b| {
                let sol = s_loop.solve_rhs(0.5, b, 1e-12).unwrap();
                assert!(sol.report.converged);
                sol.x
            })
            .collect();
        assert_columns_agree(&sols, &looped, &format!("dense/{kind}"));
    }
}

#[test]
fn block_agrees_with_looped_solves_csr_operand() {
    for kind in [SketchKind::Gaussian, SketchKind::Srht] {
        let ds = synthetic::sparse_gaussian(256, 32, 0.2, 7);
        assert!(ds.a.is_sparse(), "test premise: CSR operand");
        let bs = rhs_batch(256, 4);
        let mk = || {
            ModelSession::new(Arc::new(ds.a.clone()), ds.b.clone(), kind, 13).unwrap()
        };
        let mut s_block = mk();
        let sols = s_block.solve_block(0.4, &bs, 1e-12).unwrap();
        let mut s_loop = mk();
        let looped: Vec<Vec<f64>> = bs
            .iter()
            .map(|b| {
                let sol = s_loop.solve_rhs(0.4, b, 1e-12).unwrap();
                assert!(sol.report.converged);
                sol.x
            })
            .collect();
        assert_columns_agree(&sols, &looped, &format!("csr/{kind}"));
    }
}

#[test]
fn resumed_block_query_applies_zero_sketch() {
    let ds = synthetic::exponential_decay(256, 32, 5);
    let mut s =
        ModelSession::new(Arc::new(ds.a), ds.b, SketchKind::Gaussian, 17).unwrap();
    // Grow the sketch with a demanding single solve first.
    let first = s.solve(0.3, 1e-9).unwrap();
    assert!(first.report.converged);
    let m = s.m();
    assert!(m >= 1);
    // A block batch at a larger nu (smaller effective dimension): the
    // cached rows must be reused in full — the pinned reuse contract.
    let bs = rhs_batch(256, 4);
    let sols = s.solve_block(1.0, &bs, 1e-9).unwrap();
    for (j, sol) in sols.iter().enumerate() {
        assert!(sol.report.converged, "column {j}");
        assert_eq!(
            sol.report.sketch_time_s, 0.0,
            "resumed block query applied a fresh sketch (column {j})"
        );
        assert_eq!(sol.report.doublings, 0, "column {j} re-grew the sketch");
    }
    assert_eq!(s.m(), m, "cached sketch rows must be reused in full");
    // And the block solutions actually solve their systems.
    for (b, sol) in bs.iter().zip(&sols) {
        let p = effdim::solvers::RidgeProblem::new_shared(
            Arc::clone(s.operand()),
            b.clone(),
            1.0,
        );
        let g = p.gradient(&sol.x);
        let scale = effdim::linalg::norm2(&p.atb);
        assert!(effdim::linalg::norm2(&g) <= 1e-7 * scale);
    }
}

#[test]
fn easy_columns_retire_before_hard_ones() {
    // Column 0 is the zero RHS (optimal at x = 0, retires instantly);
    // the others are generic. Per-column iteration counts must reflect
    // the active-set shrinking.
    let ds = synthetic::exponential_decay(192, 24, 9);
    let n = 192;
    let mut bs = rhs_batch(n, 3);
    bs[0] = vec![0.0; n];
    let mut s =
        ModelSession::new(Arc::new(ds.a), ds.b, SketchKind::Gaussian, 19).unwrap();
    let sols = s.solve_block(0.5, &bs, 1e-10).unwrap();
    assert!(sols.iter().all(|sol| sol.report.converged));
    assert_eq!(sols[0].report.iterations, 0, "zero RHS must retire immediately");
    assert!(sols[0].x.iter().all(|&v| v == 0.0));
    assert!(sols[1].report.iterations >= 1 && sols[2].report.iterations >= 1);
}

#[test]
fn query_growth_triggers_eviction_under_corrected_byte_totals() {
    // Regression for the approx_bytes undercount: the post-query session
    // footprint (warm start + cached solution incl. its fixed report
    // footprint + grown sketch state) must reach the registry's running
    // total so LRU eviction fires at the right time.
    let mk_ds = |seed: u64| synthetic::exponential_decay(128, 16, seed);

    // Probe: fresh footprint vs post-query footprint of one model. The
    // probe is an exact twin of model `a` below (same data seed, same
    // sketch seed, same query), so the grown byte total is identical.
    let (fresh, grown) = {
        let probe = Registry::new(usize::MAX);
        let ds = mk_ds(2);
        let entry = probe
            .register("probe".into(), ds.a, ds.b, SketchKind::Gaussian, 2)
            .unwrap();
        let fresh = probe.total_bytes();
        let mut session = entry.session.lock().unwrap();
        session.solve(0.5, 1e-9).unwrap();
        probe.note_query(&entry, &session);
        drop(session);
        (fresh, probe.total_bytes())
    };
    assert!(
        grown > fresh,
        "a solve must grow the charged footprint (warm start, cached \
         solution, sketch state): {fresh} -> {grown}"
    );

    // Budget admits two fresh models but NOT one fresh + one grown: the
    // growth reported by note_query must evict the idle LRU model.
    let reg = Registry::new(fresh + grown - 1);
    let ds_a = mk_ds(2);
    let a = reg.register("a".into(), ds_a.a, ds_a.b, SketchKind::Gaussian, 2).unwrap().id;
    let ds_b = mk_ds(3);
    let b = reg.register("b".into(), ds_b.a, ds_b.b, SketchKind::Gaussian, 3).unwrap().id;
    assert_eq!(reg.len(), 2, "two fresh models fit the budget");

    let entry = reg.touch(a).unwrap();
    let mut session = entry.session.lock().unwrap();
    session.solve(0.5, 1e-9).unwrap();
    reg.note_query(&entry, &session);
    drop(session);

    assert_eq!(reg.len(), 1, "query growth must push the total over budget");
    assert!(reg.touch(b).is_none(), "the idle model is the LRU victim");
    assert!(reg.touch(a).is_some(), "the model serving the query is protected");
}

#[test]
fn block_solve_coexists_with_dual_of_operand_shapes() {
    // Underdetermined data still refuses a session (and hence the block
    // path) with the documented error.
    let ds = synthetic::exponential_decay(32, 16, 21);
    let wide: Operand = ds.a.transpose();
    let err = ModelSession::new(Arc::new(wide), vec![1.0; 16], SketchKind::Gaussian, 1)
        .unwrap_err();
    assert!(err.contains("overdetermined"), "{err}");
}
