//! Crash-recovery chaos suite for the durability subsystem.
//!
//! The contract under test, end to end:
//!
//! * **bitwise recovery**: a store whose history is an initial snapshot
//!   plus WAL-covered appends recovers — across all three sketch
//!   families — to a registry whose fresh queries answer
//!   bitwise-identically to a twin that never crashed;
//! * **torn tails**: a WAL cut mid-record (the shape a crash mid-write
//!   leaves behind) is truncated to the last whole record with a logged
//!   warning — never a panic, never a lost prefix;
//! * **corruption**: a bit-flipped snapshot skips that one model and
//!   recovers the rest;
//! * **failpoints**: injected faults at the three persistence sites
//!   (`persist.wal_append`, `persist.snapshot`, `persist.recover`)
//!   surface as structured errors over the wire and leave every model
//!   consistent;
//! * **spill/reload**: evict on a durable server is a spill — a later
//!   query transparently reloads the model, pending lazy appends
//!   included.
//!
//! Failpoint state is process-global, so every test serializes on one
//! mutex and starts disarmed (same discipline as `tests/chaos.rs`).

use effdim::coordinator::registry::{Registry, DEFAULT_BYTE_BUDGET};
use effdim::coordinator::server::{Client, Server, ServerConfig};
use effdim::data::synthetic;
use effdim::linalg::Matrix;
use effdim::persist::{DurabilityPolicy, Store};
use effdim::sketch::SketchKind;
use effdim::solvers::session::{AppendRefresh, ModelSession};
use effdim::util::failpoint::{self, Action};
use effdim::util::json::Json;
use effdim::Operand;
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, OnceLock};

fn chaos_lock() -> MutexGuard<'static, ()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    let guard = LOCK
        .get_or_init(|| Mutex::new(()))
        .lock()
        .unwrap_or_else(|poisoned| poisoned.into_inner());
    failpoint::disarm_all();
    guard
}

/// Fresh scratch state dir under the system temp root.
fn state_dir(tag: &str) -> PathBuf {
    static COUNTER: AtomicUsize = AtomicUsize::new(0);
    let dir = std::env::temp_dir().join(format!(
        "effdim-recovery-{tag}-{}-{}",
        std::process::id(),
        COUNTER.fetch_add(1, Ordering::Relaxed)
    ));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn assert_bitwise(x: &[f64], y: &[f64], what: &str) {
    assert_eq!(x.len(), y.len(), "{what}: length mismatch");
    for (i, (a, b)) in x.iter().zip(y).enumerate() {
        assert!(a.to_bits() == b.to_bits(), "{what}: entry {i} differs ({a:e} vs {b:e})");
    }
}

/// Deterministic `dn x d` delta block, disjoint from the generators.
fn delta_rows(dn: usize, d: usize) -> (Operand, Vec<f64>) {
    let m = Matrix::from_fn(dn, d, |i, j| ((i * d + j) as f64 * 0.017).sin());
    let b = (0..dn).map(|i| (i as f64 * 0.029).cos()).collect();
    (Operand::Dense(m), b)
}

/// Register one synthetic model on a durable registry and stream one
/// WAL-covered append into it in the server's order (WAL first, then
/// apply), then "crash": drop everything *without* a closing snapshot,
/// so recovery must replay the WAL over the initial snapshot.
fn seed_store_and_crash(dir: &Path, kind: SketchKind, refresh: AppendRefresh) -> u64 {
    let store = Arc::new(Store::open(dir, DurabilityPolicy::Strict).unwrap());
    let reg = Registry::with_store(DEFAULT_BYTE_BUDGET, Arc::clone(&store));
    let ds = synthetic::exponential_decay(192, 16, 21);
    let entry = reg.register("crash".into(), ds.a, ds.b, kind, 21).unwrap();
    let (da, db) = delta_rows(8, 16);
    {
        let mut s = entry.session.lock().unwrap();
        store
            .append_record(entry.id, &da, &db, refresh == AppendRefresh::Eager)
            .expect("append must reach the WAL");
        s.append(da, db, refresh).unwrap();
    }
    entry.id
    // reg + store drop here with the WAL ahead of the snapshot — the
    // simulated crash.
}

/// The never-crashed twin of [`seed_store_and_crash`]'s model.
fn twin_solution(kind: SketchKind, refresh: AppendRefresh, nu: f64) -> Vec<f64> {
    let ds = synthetic::exponential_decay(192, 16, 21);
    let mut twin = ModelSession::new(Arc::new(ds.a), ds.b, kind, 21).unwrap();
    let (da, db) = delta_rows(8, 16);
    twin.append(da, db, refresh).unwrap();
    twin.solve(nu, 1e-9).unwrap().x
}

// ---------------------------------------------------------------------
// Crash simulation: snapshot + WAL replay answers bitwise, per family.
// ---------------------------------------------------------------------

#[test]
fn crash_recovery_is_bitwise_for_all_sketch_families() {
    let _g = chaos_lock();
    for (kind, refresh) in [
        (SketchKind::Gaussian, AppendRefresh::Eager),
        (SketchKind::Srht, AppendRefresh::Lazy),
        (SketchKind::Sparse, AppendRefresh::Eager),
    ] {
        let dir = state_dir("families");
        let id = seed_store_and_crash(&dir, kind, refresh);
        let store = Arc::new(Store::open(&dir, DurabilityPolicy::Strict).unwrap());
        let reg = Registry::with_store(DEFAULT_BYTE_BUDGET, store);
        assert_eq!(reg.recover().unwrap(), 1, "{kind:?}");
        let entry = reg.touch(id).unwrap();
        let x = {
            let mut s = entry.session.lock().unwrap();
            assert_eq!(s.n(), 192 + 8, "{kind:?}: WAL append must replay");
            s.solve(0.4, 1e-9).unwrap().x
        };
        let twin = twin_solution(kind, refresh, 0.4);
        assert_bitwise(&x, &twin, &format!("{kind:?} recovered vs never-crashed twin"));
        let _ = std::fs::remove_dir_all(&dir);
    }
}

// ---------------------------------------------------------------------
// Torn tails and flipped bits: degrade by exactly one unit, never panic.
// ---------------------------------------------------------------------

#[test]
fn torn_wal_tail_truncates_to_the_last_whole_record() {
    let _g = chaos_lock();
    let dir = state_dir("torn");
    let store = Arc::new(Store::open(&dir, DurabilityPolicy::Strict).unwrap());
    let reg = Registry::with_store(DEFAULT_BYTE_BUDGET, Arc::clone(&store));
    let ds = synthetic::exponential_decay(192, 16, 22);
    let entry = reg.register("torn".into(), ds.a, ds.b, SketchKind::Gaussian, 22).unwrap();
    let id = entry.id;
    for _ in 0..2 {
        let (da, db) = delta_rows(4, 16);
        let mut s = entry.session.lock().unwrap();
        store.append_record(id, &da, &db, true).unwrap();
        s.append(da, db, AppendRefresh::Eager).unwrap();
    }
    drop(entry);
    drop(reg);
    drop(store);

    // Tear the tail: chop 5 bytes off the last record, as a crash
    // mid-write would.
    let wal = dir.join(id.to_string()).join("wal.log");
    let len = std::fs::metadata(&wal).unwrap().len();
    let f = std::fs::OpenOptions::new().write(true).open(&wal).unwrap();
    f.set_len(len - 5).unwrap();
    drop(f);

    let store = Arc::new(Store::open(&dir, DurabilityPolicy::Strict).unwrap());
    let reg = Registry::with_store(DEFAULT_BYTE_BUDGET, Arc::clone(&store));
    assert_eq!(reg.recover().unwrap(), 1);
    assert_eq!(store.truncated_tails.load(Ordering::Relaxed), 1, "tear must be counted");
    let entry = reg.touch(id).unwrap();
    let mut s = entry.session.lock().unwrap();
    assert_eq!(s.n(), 192 + 4, "exactly the whole-record prefix replays");
    assert!(s.solve(0.5, 1e-9).unwrap().report.converged, "recovered model still solves");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn flipped_snapshot_bit_skips_one_model_and_recovers_the_rest() {
    let _g = chaos_lock();
    let dir = state_dir("flip");
    let (id_bad, id_good) = {
        let store = Arc::new(Store::open(&dir, DurabilityPolicy::Strict).unwrap());
        let reg = Registry::with_store(DEFAULT_BYTE_BUDGET, store);
        let mk = |seed: u64| {
            let ds = synthetic::exponential_decay(96, 8, seed);
            reg.register(format!("m{seed}"), ds.a, ds.b, SketchKind::Gaussian, seed).unwrap().id
        };
        (mk(1), mk(2))
    };
    // Flip one payload bit in the middle of the first model's snapshot.
    let snap = dir.join(id_bad.to_string()).join("snapshot.snap");
    let mut bytes = Vec::new();
    std::fs::File::open(&snap).unwrap().read_to_end(&mut bytes).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x10;
    let mut f = std::fs::OpenOptions::new().write(true).open(&snap).unwrap();
    f.seek(SeekFrom::Start(0)).unwrap();
    f.write_all(&bytes).unwrap();
    drop(f);

    let store = Arc::new(Store::open(&dir, DurabilityPolicy::Strict).unwrap());
    let reg = Registry::with_store(DEFAULT_BYTE_BUDGET, store);
    assert_eq!(reg.recover().unwrap(), 1, "only the intact model recovers");
    assert!(reg.touch(id_good).is_some(), "intact model survives its neighbor's corruption");
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------
// Failpoints at the persistence sites, observed over the wire.
// ---------------------------------------------------------------------

fn start_durable_server(dir: &Path) -> (std::net::SocketAddr, Arc<std::sync::atomic::AtomicBool>, std::thread::JoinHandle<()>) {
    let config = ServerConfig {
        state_dir: Some(dir.to_path_buf()),
        durability: DurabilityPolicy::Strict,
        ..ServerConfig::default()
    };
    let server = Server::bind_with_config("127.0.0.1:0", config).unwrap();
    let addr = server.local_addr();
    let stop = server.stop_handle();
    let handle = std::thread::spawn(move || server.run());
    (addr, stop, handle)
}

fn ok_of(resp: &Json) -> bool {
    resp.get("ok").and_then(Json::as_bool) == Some(true)
}

#[test]
fn wal_append_fault_refuses_the_append_and_applies_nothing() {
    let _g = chaos_lock();
    let dir = state_dir("walfault");
    let (addr, stop, handle) = start_durable_server(&dir);
    let mut client = Client::connect(addr).unwrap();
    let reg = client
        .call(r#"{"cmd":"register","profile":"exp","n":128,"d":16,"seed":3,"name":"wf"}"#)
        .unwrap();
    assert!(ok_of(&reg), "{reg:?}");
    let model = reg.get("model").unwrap().as_usize().unwrap();

    failpoint::arm("persist.wal_append", Action::Error, 1);
    let refused = client
        .call(&format!(
            r#"{{"cmd":"append","model":{model},"rows":1,"cols":16,"triplets":[[0,3,1.0]],"b":[0.5]}}"#
        ))
        .unwrap();
    assert!(!ok_of(&refused), "{refused:?}");
    assert!(
        refused.get("error").unwrap().as_str().unwrap().contains("append not logged"),
        "{refused:?}"
    );

    // Nothing applied: the model still has its original rows and a
    // disarmed retry of the same append goes through.
    let listing = client.call(r#"{"cmd":"models"}"#).unwrap();
    let n0 = listing.get("models").unwrap().as_arr().unwrap()[0]
        .get("n")
        .unwrap()
        .as_usize()
        .unwrap();
    assert_eq!(n0, 128, "refused append must not leak rows");
    let retried = client
        .call(&format!(
            r#"{{"cmd":"append","model":{model},"rows":1,"cols":16,"triplets":[[0,3,1.0]],"b":[0.5]}}"#
        ))
        .unwrap();
    assert!(ok_of(&retried), "{retried:?}");

    stop.store(true, Ordering::SeqCst);
    handle.join().unwrap();

    // The retried (logged) append is exactly what a restart replays.
    let (addr, stop, handle) = start_durable_server(&dir);
    let mut client = Client::connect(addr).unwrap();
    let listing = client.call(r#"{"cmd":"models"}"#).unwrap();
    let n1 = listing.get("models").unwrap().as_arr().unwrap()[0]
        .get("n")
        .unwrap()
        .as_usize()
        .unwrap();
    assert_eq!(n1, 129, "recovery replays the one logged append");
    stop.store(true, Ordering::SeqCst);
    handle.join().unwrap();
    failpoint::disarm_all();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn snapshot_fault_fails_register_cleanly_and_leaves_no_ghost() {
    let _g = chaos_lock();
    let dir = state_dir("snapfault");
    let (addr, stop, handle) = start_durable_server(&dir);
    let mut client = Client::connect(addr).unwrap();

    failpoint::arm("persist.snapshot", Action::Error, 1);
    let refused = client
        .call(r#"{"cmd":"register","profile":"exp","n":96,"d":8,"seed":4,"name":"ghost"}"#)
        .unwrap();
    assert!(!ok_of(&refused), "{refused:?}");
    assert!(
        refused.get("error").unwrap().as_str().unwrap().contains("cannot persist"),
        "{refused:?}"
    );
    let health = client.call(r#"{"cmd":"health"}"#).unwrap();
    assert_eq!(health.get("models").unwrap().as_usize(), Some(0), "{health:?}");

    // Disarmed, the same registration succeeds and is durable.
    let reg = client
        .call(r#"{"cmd":"register","profile":"exp","n":96,"d":8,"seed":4,"name":"ghost"}"#)
        .unwrap();
    assert!(ok_of(&reg), "{reg:?}");
    stop.store(true, Ordering::SeqCst);
    handle.join().unwrap();
    failpoint::disarm_all();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn recover_fault_skips_the_model_then_reloads_it_on_demand() {
    let _g = chaos_lock();
    let dir = state_dir("recfault");
    // Two models, cleanly shut down.
    let (addr, stop, handle) = start_durable_server(&dir);
    let mut client = Client::connect(addr).unwrap();
    let mut ids = Vec::new();
    for seed in [5, 6] {
        let reg = client
            .call(&format!(
                r#"{{"cmd":"register","profile":"exp","n":96,"d":8,"seed":{seed},"name":"r{seed}"}}"#
            ))
            .unwrap();
        assert!(ok_of(&reg), "{reg:?}");
        ids.push(reg.get("model").unwrap().as_usize().unwrap());
    }
    stop.store(true, Ordering::SeqCst);
    handle.join().unwrap();

    // One injected rebuild fault: startup recovery skips that model with
    // a warning and carries on.
    failpoint::arm("persist.recover", Action::Error, 1);
    let (addr, stop, handle) = start_durable_server(&dir);
    let mut client = Client::connect(addr).unwrap();
    let health = client.call(r#"{"cmd":"health"}"#).unwrap();
    assert_eq!(health.get("models").unwrap().as_usize(), Some(1), "{health:?}");

    // The skipped model's disk state is intact, so a (now disarmed)
    // query reloads it transparently instead of erroring.
    let q = client
        .call(&format!(r#"{{"cmd":"query","model":{},"nu":0.5,"eps":1e-8}}"#, ids[0]))
        .unwrap();
    assert!(ok_of(&q), "skipped model must reload on demand: {q:?}");
    let health = client.call(r#"{"cmd":"health"}"#).unwrap();
    assert_eq!(health.get("models").unwrap().as_usize(), Some(2), "{health:?}");
    stop.store(true, Ordering::SeqCst);
    handle.join().unwrap();
    failpoint::disarm_all();
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------
// Spill / reload over the wire, pending lazy appends included.
// ---------------------------------------------------------------------

#[test]
fn evicted_model_reloads_on_demand_with_its_pending_lazy_append() {
    let _g = chaos_lock();
    let dir = state_dir("spill");
    let (addr, stop, handle) = start_durable_server(&dir);
    let mut client = Client::connect(addr).unwrap();
    let reg = client
        .call(r#"{"cmd":"register","profile":"exp","n":128,"d":16,"seed":7,"name":"sp"}"#)
        .unwrap();
    assert!(ok_of(&reg), "{reg:?}");
    let model = reg.get("model").unwrap().as_usize().unwrap();

    // Lazy append: the delta sits in the session's pending buffer when
    // the evict lands — the old data-loss shape.
    let app = client
        .call(&format!(
            r#"{{"cmd":"append","model":{model},"rows":1,"cols":16,"triplets":[[0,2,2.0]],"b":[0.25],"refresh":"lazy"}}"#
        ))
        .unwrap();
    assert!(ok_of(&app), "{app:?}");

    let ev = client.call(&format!(r#"{{"cmd":"evict","model":{model}}}"#)).unwrap();
    assert!(ok_of(&ev), "{ev:?}");
    assert_eq!(ev.get("purged").and_then(Json::as_bool), Some(false), "{ev:?}");

    // The next query transparently reloads from disk; the appended row
    // is there.
    let q = client
        .call(&format!(r#"{{"cmd":"query","model":{model},"nu":0.5,"eps":1e-8}}"#))
        .unwrap();
    assert!(ok_of(&q), "spilled model must reload: {q:?}");
    let listing = client.call(r#"{"cmd":"models"}"#).unwrap();
    let n = listing.get("models").unwrap().as_arr().unwrap()[0]
        .get("n")
        .unwrap()
        .as_usize()
        .unwrap();
    assert_eq!(n, 129, "pending lazy append survived the spill");

    // Purge is final: no transparent reload afterwards.
    let ev = client.call(&format!(r#"{{"cmd":"evict","model":{model},"purge":true}}"#)).unwrap();
    assert!(ok_of(&ev), "{ev:?}");
    assert_eq!(ev.get("purged").and_then(Json::as_bool), Some(true), "{ev:?}");
    let q = client
        .call(&format!(r#"{{"cmd":"query","model":{model},"nu":0.5,"eps":1e-8}}"#))
        .unwrap();
    assert!(!ok_of(&q), "purged model must stay gone: {q:?}");

    stop.store(true, Ordering::SeqCst);
    handle.join().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}
