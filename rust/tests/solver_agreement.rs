//! Integration: every solver in the stack agrees with the direct solution
//! on shared problems, including across embeddings and the dual path.

use effdim::data::synthetic;
use effdim::linalg::norm2;
use effdim::rng::Xoshiro256;
use effdim::sketch::SketchKind;
use effdim::solvers::adaptive::{self, AdaptiveConfig, AdaptiveVariant};
use effdim::solvers::cg::{self, CgConfig};
use effdim::solvers::dual::{dual_stop, solve_direct, DualRidge};
use effdim::solvers::pcg::{self, PcgConfig};
use effdim::solvers::{direct, RidgeProblem, StopRule};

fn rel_err(x: &[f64], x_star: &[f64]) -> f64 {
    let mut diff = x.to_vec();
    for i in 0..x.len() {
        diff[i] -= x_star[i];
    }
    norm2(&diff) / norm2(x_star).max(1e-300)
}

#[test]
fn all_solvers_agree_on_mnist_like() {
    let ds = synthetic::mnist_like(512, 64, 1);
    let nu = 0.5;
    let p = RidgeProblem::new(ds.a.clone(), ds.b.clone(), nu);
    let x_star = direct::solve(&p);
    let stop = StopRule::TrueError { x_star: x_star.clone(), eps: 1e-10 };
    let x0 = vec![0.0; 64];

    // The paper's criterion is the prediction norm delta_t/delta_0; the
    // x-space translation is weaker by the conditioning (sigma_1/nu ~ 80
    // here), so check delta-convergence exactly and x-space loosely.
    let cg_sol = cg::solve(&p, &x0, &CgConfig { max_iters: 50_000, stop: stop.clone() });
    assert!(cg_sol.report.converged && cg_sol.report.final_rel_error.unwrap() <= 1e-10, "cg");
    assert!(rel_err(&cg_sol.x, &x_star) < 1e-2, "cg x-space");

    let mut rng = Xoshiro256::seed_from_u64(2);
    let pcg_sol = pcg::solve(&p, &x0, &PcgConfig::new(SketchKind::Srht, 0.5, stop.clone()), &mut rng);
    assert!(pcg_sol.report.converged, "pcg");
    assert!(rel_err(&pcg_sol.x, &x_star) < 1e-2, "pcg x-space");

    for kind in [SketchKind::Gaussian, SketchKind::Srht, SketchKind::Sparse] {
        for variant in [AdaptiveVariant::PolyakFirst, AdaptiveVariant::GradientOnly] {
            let mut cfg = AdaptiveConfig::new(kind, stop.clone());
            cfg.variant = variant;
            let sol = adaptive::solve(&p, &x0, &cfg, 3);
            assert!(
                sol.report.converged && rel_err(&sol.x, &x_star) < 1e-2,
                "adaptive {kind} {variant:?}: rel {}",
                rel_err(&sol.x, &x_star)
            );
        }
    }
}

#[test]
fn primal_and_dual_agree_on_square_ish_problem() {
    // d slightly >= n: solve the same data through the dual and compare
    // with the primal direct solve applied to the transpose formulation.
    let base = synthetic::exponential_decay(128, 32, 4);
    let a_wide = base.a.transpose(); // 32 x 128
    let mut rng = Xoshiro256::seed_from_u64(5);
    let mut b = vec![0.0; 32];
    rng.fill_gaussian(&mut b, 1.0);
    let nu = 0.7;

    let x_exact = solve_direct(&a_wide, &b, nu);
    let dr = DualRidge::new(a_wide.clone(), b.clone(), nu);
    let cfg = AdaptiveConfig::new(SketchKind::Gaussian, dual_stop(&dr.dual, 1e-12));
    let sol = dr.solve_adaptive(&cfg, 6);
    assert!(sol.report.converged);
    assert!(rel_err(&sol.x, &x_exact) < 1e-4);
}

#[test]
fn regularization_shift_matches_theory() {
    // x*(nu) shrinks along the path; consecutive path solutions must obey
    // the monotone norm property of ridge regression.
    let ds = synthetic::polynomial_decay(256, 32, 7);
    let norms: Vec<f64> = [0.01, 0.1, 1.0, 10.0]
        .iter()
        .map(|&nu| {
            let p = RidgeProblem::new(ds.a.clone(), ds.b.clone(), nu);
            norm2(&direct::solve(&p))
        })
        .collect();
    for w in norms.windows(2) {
        assert!(w[1] <= w[0] + 1e-12, "||x*|| must shrink with nu: {norms:?}");
    }
}

#[test]
fn adaptive_rate_matches_theorem_6_envelope() {
    // SRHT: delta_t / delta_1 <= 2 (1 + sigma1^2/nu^2) c_gd^{t-1}.
    let ds = synthetic::exponential_decay(512, 32, 8);
    let nu = 0.5;
    let p = RidgeProblem::new(ds.a.clone(), ds.b.clone(), nu);
    let x_star = direct::solve(&p);
    let stop = StopRule::TrueError { x_star, eps: 1e-12 };
    let cfg = AdaptiveConfig::new(SketchKind::Srht, stop);
    let sol = adaptive::solve(&p, &vec![0.0; 32], &cfg, 9);
    let c_gd = cfg.params().c_gd;
    let prefactor = effdim::theory::bounds::srht_error_prefactor(ds.sigma[0], nu);
    for (i, rel) in sol.report.error_trace.iter().enumerate() {
        let envelope = prefactor * c_gd.powi(i as i32);
        assert!(
            *rel <= envelope.max(1e-12) * 1.001,
            "iteration {i}: rel {rel} above Theorem-6 envelope {envelope}"
        );
    }
}
