//! Integration: every solver in the stack agrees with the direct solution
//! on shared problems, including across embeddings and the dual path.
//!
//! The first two tests iterate the [`effdim::solvers::registry`]: every
//! spec the library advertises must round-trip through its string form
//! and converge to the direct solution through the unified
//! [`Solver`](effdim::solvers::Solver) dispatch — there is no separate
//! per-solver plumbing to keep in sync.

use effdim::data::synthetic;
use effdim::linalg::norm2;
use effdim::sketch::SketchKind;
use effdim::solvers::adaptive::{self, AdaptiveConfig, AdaptiveVariant};
use effdim::solvers::cg::{self, CgConfig};
use effdim::solvers::dual::{dual_stop, solve_direct, DualRidge};
use effdim::solvers::pcg::{self, PcgConfig};
use effdim::solvers::{direct, registry, RidgeProblem, Solver as _, SolverSpec, StopRule};

fn rel_err(x: &[f64], x_star: &[f64]) -> f64 {
    let mut diff = x.to_vec();
    for i in 0..x.len() {
        diff[i] -= x_star[i];
    }
    norm2(&diff) / norm2(x_star).max(1e-300)
}

#[test]
fn spec_strings_roundtrip_for_every_registry_entry() {
    for spec in registry() {
        let s = spec.to_string();
        let back: SolverSpec = s.parse().unwrap_or_else(|e| panic!("parse {s:?}: {e}"));
        assert_eq!(back, spec, "Display/FromStr round-trip broke for {s:?}");
        // The built solver's label is the spec string itself.
        assert_eq!(spec.build(1).label(), s);
    }
}

#[test]
fn every_registry_solver_agrees_with_direct() {
    // Square problem (n = d) so the dual reduction applies alongside the
    // overdetermined solvers; nu = 1.0 keeps d_e small, the regime every
    // family handles.
    let ds = synthetic::exponential_decay(64, 64, 1);
    let nu = 1.0;
    let p = RidgeProblem::new(ds.a.clone(), ds.b.clone(), nu);
    let x_star = direct::solve(&p);
    let stop = StopRule::TrueError { x_star: x_star.clone(), eps: 1e-8 };
    let x0 = vec![0.0; p.d()];

    for spec in registry() {
        let solver = spec.build(3);
        let sol = solver.solve(&p, &x0, &stop);
        assert!(
            sol.report.converged,
            "{spec} did not converge (rel {:?})",
            sol.report.final_rel_error
        );
        assert_eq!(sol.report.solver, spec.to_string(), "label drift for {spec}");
        // The paper's criterion is the prediction norm; the x-space
        // translation is weaker by the conditioning, so check loosely.
        assert!(
            rel_err(&sol.x, &x_star) < 1e-2,
            "{spec} x-space error {}",
            rel_err(&sol.x, &x_star)
        );
    }
}

#[test]
fn all_solvers_agree_on_mnist_like() {
    let ds = synthetic::mnist_like(512, 64, 1);
    let nu = 0.5;
    let p = RidgeProblem::new(ds.a.clone(), ds.b.clone(), nu);
    let x_star = direct::solve(&p);
    let stop = StopRule::TrueError { x_star: x_star.clone(), eps: 1e-10 };
    let x0 = vec![0.0; 64];

    // The paper's criterion is the prediction norm delta_t/delta_0; the
    // x-space translation is weaker by the conditioning (sigma_1/nu ~ 80
    // here), so check delta-convergence exactly and x-space loosely.
    let cg_sol = cg::solve(&p, &x0, &CgConfig { max_iters: 50_000 }, &stop);
    assert!(cg_sol.report.converged && cg_sol.report.final_rel_error.unwrap() <= 1e-10, "cg");
    assert!(rel_err(&cg_sol.x, &x_star) < 1e-2, "cg x-space");

    let pcg_sol = pcg::solve(&p, &x0, &PcgConfig::new(SketchKind::Srht, 0.5), &stop, 2);
    assert!(pcg_sol.report.converged, "pcg");
    assert!(rel_err(&pcg_sol.x, &x_star) < 1e-2, "pcg x-space");

    for kind in [SketchKind::Gaussian, SketchKind::Srht, SketchKind::Sparse] {
        for variant in [AdaptiveVariant::PolyakFirst, AdaptiveVariant::GradientOnly] {
            let mut cfg = AdaptiveConfig::new(kind);
            cfg.variant = variant;
            let sol = adaptive::solve(&p, &x0, &cfg, &stop, 3).unwrap();
            assert!(
                sol.report.converged && rel_err(&sol.x, &x_star) < 1e-2,
                "adaptive {kind} {variant:?}: rel {}",
                rel_err(&sol.x, &x_star)
            );
        }
    }
}

#[test]
fn primal_and_dual_agree_on_square_ish_problem() {
    // d slightly >= n: solve the same data through the dual and compare
    // with the primal direct solve applied to the transpose formulation.
    let base = synthetic::exponential_decay(128, 32, 4);
    let a_wide = base.a.transpose(); // 32 x 128
    let mut rng = effdim::rng::Xoshiro256::seed_from_u64(5);
    let mut b = vec![0.0; 32];
    rng.fill_gaussian(&mut b, 1.0);
    let nu = 0.7;

    let x_exact = solve_direct(&a_wide, &b, nu);

    // Low-level dual API...
    let dr = DualRidge::new(a_wide.clone(), b.clone(), nu);
    let cfg = AdaptiveConfig::new(SketchKind::Gaussian);
    let sol = dr.solve_adaptive(&cfg, &dual_stop(&dr.dual, 1e-12), 6);
    assert!(sol.report.converged);
    assert!(rel_err(&sol.x, &x_exact) < 1e-4);

    // ...and the same through the unified spec dispatch.
    let p_wide = RidgeProblem::new(a_wide, b, nu);
    let spec: SolverSpec = "dual-adaptive-gaussian".parse().unwrap();
    let stop = StopRule::TrueError { x_star: x_exact.clone(), eps: 1e-12 };
    let sol2 = spec.build(6).solve(&p_wide, &vec![0.0; p_wide.d()], &stop);
    assert!(sol2.report.converged);
    assert_eq!(sol2.report.solver, "dual-adaptive-gaussian");
    assert!(rel_err(&sol2.x, &x_exact) < 1e-4);
}

#[test]
fn regularization_shift_matches_theory() {
    // x*(nu) shrinks along the path; consecutive path solutions must obey
    // the monotone norm property of ridge regression.
    let ds = synthetic::polynomial_decay(256, 32, 7);
    let norms: Vec<f64> = [0.01, 0.1, 1.0, 10.0]
        .iter()
        .map(|&nu| {
            let p = RidgeProblem::new(ds.a.clone(), ds.b.clone(), nu);
            norm2(&direct::solve(&p))
        })
        .collect();
    for w in norms.windows(2) {
        assert!(w[1] <= w[0] + 1e-12, "||x*|| must shrink with nu: {norms:?}");
    }
}

#[test]
fn adaptive_rate_matches_theorem_6_envelope() {
    // SRHT: delta_t / delta_1 <= 2 (1 + sigma1^2/nu^2) c_gd^{t-1}.
    let ds = synthetic::exponential_decay(512, 32, 8);
    let nu = 0.5;
    let p = RidgeProblem::new(ds.a.clone(), ds.b.clone(), nu);
    let x_star = direct::solve(&p);
    let stop = StopRule::TrueError { x_star, eps: 1e-12 };
    let cfg = AdaptiveConfig::new(SketchKind::Srht);
    let sol = adaptive::solve(&p, &vec![0.0; 32], &cfg, &stop, 9).unwrap();
    let c_gd = cfg.params().c_gd;
    let prefactor = effdim::theory::bounds::srht_error_prefactor(ds.sigma[0], nu);
    // Trace convention: entry 0 is the trivial 1.0 starting point; entry
    // t >= 1 is delta_t / delta_0, bounded by prefactor * c_gd^(t-1).
    assert_eq!(sol.report.error_trace[0], 1.0);
    for (t, rel) in sol.report.error_trace.iter().enumerate().skip(1) {
        let envelope = prefactor * c_gd.powi(t as i32 - 1);
        assert!(
            *rel <= envelope.max(1e-12) * 1.001,
            "iteration {t}: rel {rel} above Theorem-6 envelope {envelope}"
        );
    }
}
