//! Streaming-ingest acceptance sweep (`ModelSession::append` + the
//! engine's row-append path): an appended model must answer exactly like
//! a model registered fresh on the concatenated data, for every sketch
//! family and both operand storages, while never re-sketching the
//! retained rows.

use effdim::linalg::sparse::CsrMatrix;
use effdim::linalg::{norm2, Matrix, Operand};
use effdim::rng::Xoshiro256;
use effdim::sketch::engine::SketchEngine;
use effdim::sketch::SketchKind;
use effdim::solvers::session::{AppendRefresh, ModelSession};
use std::sync::Arc;

const KINDS: [SketchKind; 3] = [SketchKind::Gaussian, SketchKind::Srht, SketchKind::Sparse];

/// Deterministic full problem of `n + dn` rows, split into the base block
/// and the streamed delta. `density < 1` zeroes entries so the CSR
/// storage variants exercise genuinely sparse deltas.
fn split_problem(
    n: usize,
    dn: usize,
    d: usize,
    density: f64,
    seed: u64,
) -> (Matrix, Vec<f64>, Matrix, Vec<f64>, Matrix, Vec<f64>) {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let full = Matrix::from_fn(n + dn, d, |_, _| {
        if rng.next_f64() < density {
            rng.next_gaussian()
        } else {
            0.0
        }
    });
    let b_full: Vec<f64> = (0..n + dn).map(|i| (i as f64 * 0.011).sin()).collect();
    let base = Matrix::from_fn(n, d, |i, j| full.get(i, j));
    let delta = Matrix::from_fn(dn, d, |i, j| full.get(n + i, j));
    let b_base = b_full[..n].to_vec();
    let b_delta = b_full[n..].to_vec();
    (full, b_full, base, b_base, delta, b_delta)
}

/// Relative agreement between two solutions of the same problem.
fn rel_diff(x: &[f64], y: &[f64]) -> f64 {
    let diff: Vec<f64> = x.iter().zip(y).map(|(a, b)| a - b).collect();
    norm2(&diff) / (1.0 + norm2(x))
}

#[test]
fn appended_model_matches_fresh_register_for_every_kind_and_storage() {
    let (n, dn, d) = (192, 12, 16);
    let (nu, eps) = (0.7, 1e-12);
    for kind in KINDS {
        for sparse_storage in [false, true] {
            let (full, b_full, base, b_base, delta, b_delta) =
                split_problem(n, dn, d, if sparse_storage { 0.4 } else { 1.0 }, 9);
            let wrap = |m: &Matrix| -> Operand {
                if sparse_storage {
                    Operand::Sparse(CsrMatrix::from_dense(m))
                } else {
                    Operand::Dense(m.clone())
                }
            };
            let mut appended =
                ModelSession::new(Arc::new(wrap(&base)), b_base, kind, 5).unwrap();
            appended.solve(nu, eps).unwrap(); // warm: sketch grown on the base rows
            let m_before = appended.m();
            let out = appended
                .append(wrap(&delta), b_delta, AppendRefresh::Eager)
                .unwrap();
            assert_eq!(out.rows_added, dn);
            assert_eq!(out.n, n + dn);
            assert_eq!(out.m, m_before, "append must not change the sketch size");
            let x_app = appended.solve(nu, eps).unwrap().x;

            let mut fresh = ModelSession::new(Arc::new(wrap(&full)), b_full, kind, 5).unwrap();
            let x_fresh = fresh.solve(nu, eps).unwrap().x;
            let diff = rel_diff(&x_app, &x_fresh);
            assert!(
                diff <= 1e-10,
                "append vs fresh register disagree: {diff:.3e} \
                 (kind {kind}, sparse_storage {sparse_storage})"
            );
        }
    }
}

#[test]
fn lazy_appends_accumulate_and_match_fresh_register() {
    // Two lazy deltas (one dense, one CSR) then a solve: the deferred
    // refresh must fold BOTH pending blocks in before answering, and the
    // answer must match a fresh model on the full concatenation.
    let (n, dn, d) = (160, 10, 12);
    let (nu, eps) = (0.5, 1e-12);
    for kind in KINDS {
        let (full, b_full, base, b_base, delta, b_delta) = split_problem(n, 2 * dn, d, 1.0, 21);
        let d1 = Matrix::from_fn(dn, d, |i, j| delta.get(i, j));
        let d2 = Matrix::from_fn(dn, d, |i, j| delta.get(dn + i, j));
        let mut sess = ModelSession::new(
            Arc::new(Operand::Dense(base)),
            b_base,
            kind,
            3,
        )
        .unwrap();
        sess.solve(nu, eps).unwrap();
        let out1 = sess
            .append(Operand::Dense(d1), b_delta[..dn].to_vec(), AppendRefresh::Lazy)
            .unwrap();
        assert!(!out1.refreshed, "lazy append defers the downstream refresh");
        let out2 = sess
            .append(
                Operand::Sparse(CsrMatrix::from_dense(&d2)),
                b_delta[dn..].to_vec(),
                AppendRefresh::Lazy,
            )
            .unwrap();
        assert_eq!(out2.n, n + 2 * dn);
        let x_app = sess.solve(nu, eps).unwrap().x;

        let mut fresh =
            ModelSession::new(Arc::new(Operand::Dense(full)), b_full, kind, 3).unwrap();
        let x_fresh = fresh.solve(nu, eps).unwrap().x;
        let diff = rel_diff(&x_app, &x_fresh);
        assert!(diff <= 1e-10, "lazy appends disagree with fresh: {diff:.3e} (kind {kind})");
    }
}

#[test]
fn append_never_resketches_retained_rows() {
    // The re-solve after an append may GROW the sketch (doublings > 0,
    // which sketches only the new rows) but must never pay a from-scratch
    // re-apply: with no growth, its sketch time is exactly zero, and the
    // sketch size is untouched by the append itself.
    let (n, dn, d) = (192, 8, 16);
    let (nu, eps) = (0.5, 1e-8);
    for kind in KINDS {
        let (_, _, base, b_base, delta, b_delta) = split_problem(n, dn, d, 1.0, 4);
        let mut sess =
            ModelSession::new(Arc::new(Operand::Dense(base)), b_base, kind, 11).unwrap();
        sess.solve(nu, eps).unwrap();
        let m_before = sess.m();
        sess.append(Operand::Dense(delta), b_delta, AppendRefresh::Eager).unwrap();
        assert_eq!(sess.m(), m_before);
        let report = sess.solve(nu, eps).unwrap().report;
        assert!(
            report.sketch_time_s == 0.0 || report.doublings > 0,
            "solve after append paid sketch time without growing (kind {kind})"
        );
    }
}

#[test]
fn engine_growth_after_append_keeps_the_sketch_prefix_bitwise() {
    // Growing the sketch after a row append must only add rows: the
    // retained `S~A` entries stay bitwise identical, for every family.
    let (n, dn, d, m) = (192, 12, 16, 8);
    for kind in KINDS {
        let mut rng = Xoshiro256::seed_from_u64(7);
        let (full, _, base, _, delta, _) = split_problem(n, dn, d, 1.0, 13);
        let mut engine = SketchEngine::new(kind, m, &base, &mut rng);
        engine.append_rows(&delta, &mut rng).unwrap();
        assert_eq!(engine.n(), n + dn);
        assert_eq!(engine.m(), m);
        let before = engine.sa_unnormalized().clone();
        let target = (2 * m).min(engine.max_m());
        assert!(target > m, "growth target must exceed m for the test to bite");
        engine.grow(target, &full, &mut rng).unwrap();
        assert_eq!(engine.m(), target);
        let after = engine.sa_unnormalized();
        for i in 0..m {
            for j in 0..d {
                assert!(
                    before.get(i, j).to_bits() == after.get(i, j).to_bits(),
                    "growth rewrote retained sketch row {i} (kind {kind})"
                );
            }
        }
    }
}

#[test]
fn append_warm_start_cuts_iterations_vs_cold_register() {
    // The appended session keeps its previous solution as the warm start;
    // for dn << n the re-solve must take no more iterations than a cold
    // model registered fresh on the concatenated data.
    let (n, dn, d) = (256, 8, 16);
    let (nu, eps) = (0.5, 1e-10);
    let (full, b_full, base, b_base, delta, b_delta) = split_problem(n, dn, d, 1.0, 17);
    let mut warm = ModelSession::new(
        Arc::new(Operand::Dense(base)),
        b_base,
        SketchKind::Gaussian,
        19,
    )
    .unwrap();
    warm.solve(nu, eps).unwrap();
    warm.append(Operand::Dense(delta), b_delta, AppendRefresh::Eager).unwrap();
    let warm_iters = warm.solve(nu, eps).unwrap().report.iterations;

    let mut cold =
        ModelSession::new(Arc::new(Operand::Dense(full)), b_full, SketchKind::Gaussian, 19)
            .unwrap();
    let cold_iters = cold.solve(nu, eps).unwrap().report.iterations;
    assert!(
        warm_iters <= cold_iters,
        "warm re-solve after append took {warm_iters} iterations vs {cold_iters} cold"
    );
}
