//! Integration: incremental sketch growth is deterministic, prefix-
//! consistent, and transparent to the solvers.
//!
//! The contracts under test (see `sketch::engine`):
//! * a grown sketch agrees *exactly* with its own pre-growth prefix
//!   (unnormalized rows are append-only);
//! * `grow`-then-apply matches the dense composition `to_dense() * A`
//!   within 1e-10 at every growth step;
//! * the grown Woodbury cache applies the same inverse as a from-scratch
//!   factorization of the same rows;
//! * the adaptive solvers (which now always take the growth-reuse path)
//!   stay deterministic given a seed and still converge to the direct
//!   solution — the registry-wide agreement test in `solver_agreement.rs`
//!   covers every spec; here we additionally pin the growth internals.

use effdim::data::synthetic;
use effdim::linalg::Matrix;
use effdim::rng::Xoshiro256;
use effdim::sketch::engine::SketchEngine;
use effdim::sketch::SketchKind;
use effdim::solvers::woodbury::WoodburyCache;
use effdim::solvers::{direct, registry, RidgeProblem, Solver as _, StopRule};

const KINDS: [SketchKind; 3] = [SketchKind::Gaussian, SketchKind::Srht, SketchKind::Sparse];

fn test_a(n: usize, d: usize, seed: u64) -> Matrix {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    Matrix::from_fn(n, d, |_, _| rng.next_gaussian())
}

#[test]
fn growth_is_deterministic_and_prefix_consistent() {
    let a = test_a(48, 9, 1);
    for kind in KINDS {
        let run = |grows: &[usize]| {
            let mut rng = Xoshiro256::seed_from_u64(2);
            let mut engine = SketchEngine::new(kind, 2, &a, &mut rng);
            let mut snapshots = vec![engine.sa_unnormalized().clone()];
            for &m in grows {
                engine.grow(m, &a, &mut rng).unwrap();
                snapshots.push(engine.sa_unnormalized().clone());
            }
            snapshots
        };
        let snaps = run(&[5, 12, 30]);
        // Determinism: a second identical run reproduces every state.
        let again = run(&[5, 12, 30]);
        assert_eq!(snaps.len(), again.len());
        for (s1, s2) in snaps.iter().zip(&again) {
            assert_eq!(s1, s2, "{kind} growth not deterministic");
        }
        // Prefix consistency: each snapshot is an exact prefix of the next.
        for w in snaps.windows(2) {
            let (small, big) = (&w[0], &w[1]);
            for i in 0..small.rows() {
                assert_eq!(small.row(i), big.row(i), "{kind} prefix row {i} drifted");
            }
        }
    }
}

#[test]
fn grow_then_apply_matches_dense_composition() {
    // n = 40 pads to 64, exercising the SRHT padding path.
    let a = test_a(40, 11, 3);
    for kind in KINDS {
        let mut rng = Xoshiro256::seed_from_u64(4);
        let mut engine = SketchEngine::new(kind, 3, &a, &mut rng);
        for &m in &[7usize, 16, 33] {
            engine.grow(m, &a, &mut rng).unwrap();
            let mut scaled = engine.sa_unnormalized().clone();
            effdim::linalg::scale(engine.scale(), scaled.as_mut_slice());
            let composed = engine.to_dense().matmul(&a);
            assert!(
                scaled.max_abs_diff(&composed) < 1e-10,
                "{kind} at m={m}: grown apply != dense composition"
            );
        }
    }
}

#[test]
fn grown_woodbury_matches_from_scratch_through_engine_rows() {
    // Drive the exact (engine, cache) pair the adaptive solver uses
    // through several doublings and compare against fresh factorizations.
    let d = 12;
    let a = test_a(64, d, 5);
    let nu = 0.7;
    for kind in KINDS {
        let mut rng = Xoshiro256::seed_from_u64(6);
        let mut engine = SketchEngine::new(kind, 1, &a, &mut rng);
        let mut cache =
            WoodburyCache::new_scaled(engine.sa_unnormalized().clone(), nu, engine.scale())
                .unwrap();
        let g: Vec<f64> = (0..d).map(|i| (i as f64 * 0.23).sin()).collect();
        for &m in &[2usize, 4, 8, 16, 32] {
            let new_rows = engine.grow(m, &a, &mut rng).unwrap();
            cache.grow(&new_rows, engine.scale()).unwrap();
            let fresh =
                WoodburyCache::new_scaled(engine.sa_unnormalized().clone(), nu, engine.scale())
                    .unwrap();
            let zg = cache.apply_inverse(&g);
            let zf = fresh.apply_inverse(&g);
            for i in 0..d {
                assert!(
                    (zg[i] - zf[i]).abs() < 1e-8,
                    "{kind} m={m} coord {i}: grown {} vs fresh {}",
                    zg[i],
                    zf[i]
                );
            }
        }
    }
}

#[test]
fn adaptive_with_growth_reuse_converges_and_is_seed_deterministic() {
    let ds = synthetic::exponential_decay(256, 32, 7);
    let p = RidgeProblem::new(ds.a.clone(), ds.b.clone(), 0.5);
    let x_star = direct::solve(&p);
    let stop = StopRule::TrueError { x_star, eps: 1e-9 };
    let x0 = vec![0.0; 32];
    for solver in ["adaptive-gaussian", "adaptive-srht", "adaptive-sparse", "adaptive-gd-srht"] {
        let spec: effdim::SolverSpec = solver.parse().unwrap();
        let s1 = spec.build(11).solve(&p, &x0, &stop);
        let s2 = spec.build(11).solve(&p, &x0, &stop);
        assert!(s1.report.converged, "{solver} failed to converge");
        assert_eq!(s1.x, s2.x, "{solver} not deterministic given seed");
        assert_eq!(s1.report.m_trace, s2.report.m_trace);
        // Growth happened through the engine: the m-trace never shrinks.
        for w in s1.report.m_trace.windows(2) {
            assert!(w[1] >= w[0]);
        }
    }
}

#[test]
fn registry_agreement_with_growth_reuse_on() {
    // Growth reuse is always on — every registry solver must still land on
    // the direct solution (mirrors solver_agreement.rs on a second
    // problem shape to cover the growth-heavy small-nu regime).
    let ds = synthetic::exponential_decay(128, 32, 8);
    let p = RidgeProblem::new(ds.a.clone(), ds.b.clone(), 0.3);
    let x_star = direct::solve(&p);
    let stop = StopRule::TrueError { x_star: x_star.clone(), eps: 1e-8 };
    let x0 = vec![0.0; 32];
    for spec in registry() {
        if matches!(spec, effdim::SolverSpec::DualAdaptive { .. }) {
            continue; // needs d >= n
        }
        let sol = spec.build(13).solve(&p, &x0, &stop);
        assert!(sol.report.converged, "{spec} did not converge with growth reuse on");
    }
}

#[test]
fn sketch_and_factor_times_reflect_incremental_growth() {
    // The report buckets must stay consistent under the incremental path:
    // both phases are populated and bounded by the wall clock.
    let ds = synthetic::exponential_decay(512, 64, 9);
    let p = RidgeProblem::new(ds.a.clone(), ds.b.clone(), 0.05); // small nu -> real growth
    let x_star = direct::solve(&p);
    let stop = StopRule::TrueError { x_star, eps: 1e-9 };
    let spec: effdim::SolverSpec = "adaptive-srht".parse().unwrap();
    let sol = spec.build(15).solve(&p, &vec![0.0; 64], &stop);
    assert!(sol.report.converged);
    let r = &sol.report;
    assert!(r.sketch_time_s >= 0.0 && r.factor_time_s >= 0.0);
    assert!(
        r.sketch_time_s + r.factor_time_s <= r.wall_time_s + 0.05,
        "phase times {} + {} exceed wall {}",
        r.sketch_time_s,
        r.factor_time_s,
        r.wall_time_s
    );
    if r.doublings > 0 {
        // Growth happened: the engine recorded per-growth work in both
        // buckets (strictly positive since the initial sketch alone is).
        assert!(r.sketch_time_s > 0.0);
        assert!(r.factor_time_s > 0.0);
    }
}
