//! Multi-reader serving core: the lock-free snapshot battery.
//!
//! The serving stack publishes each model's state as an immutable
//! [`SessionSnapshot`] behind an RCU cell ([`ModelEntry::snapshot`] /
//! [`ModelEntry::publish`]); this suite pins the three claims that make
//! that safe to serve from:
//!
//! * **no locks on the read path** — a reader answers repeat-`nu` /
//!   cached queries from the snapshot handle alone, even while a writer
//!   holds the session mutex indefinitely;
//! * **no torn reads** — every snapshot any reader ever loads is
//!   bitwise-identical to one of the legal generations a serialized
//!   writer published (never a mix of two), and generations are
//!   monotone per reader;
//! * **crash-safe publication** — a writer that dies (injected error or
//!   panic) between commit and publish leaves the *old* snapshot live
//!   and fully correct; no partial snapshot is ever observable.
//! * **lock-free uncached solves** — the frozen lane
//!   ([`SessionSnapshot::solve_frozen`]) answers *uncached* distinct-`nu`
//!   queries from the pinned artifacts alone (no session lock), bitwise
//!   equal to the writer lane, deferring with
//!   [`FrozenOutcome::NeedsGrowth`] exactly when the writer would grow.
//!
//! The `session.publish` failpoint is process-global state, so every
//! test here serializes on one suite mutex and starts disarmed, exactly
//! like `tests/chaos.rs` (armed sites must never leak across tests
//! sharing the process).

use effdim::coordinator::registry::{ModelEntry, Registry, DEFAULT_BYTE_BUDGET};
use effdim::data::synthetic;
use effdim::linalg::Matrix;
use effdim::sketch::SketchKind;
use effdim::solvers::adaptive::FrozenOutcome;
use effdim::solvers::session::{AppendRefresh, ModelSession, SessionSnapshot};
use effdim::util::failpoint::{self, Action};
use effdim::Operand;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, OnceLock};
use std::thread;
use std::time::Duration;

const EPS: f64 = 1e-8;

/// Serialize the suite (failpoints are process-global) and start each
/// test from a disarmed registry.
fn suite_lock() -> MutexGuard<'static, ()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    let guard = LOCK
        .get_or_init(|| Mutex::new(()))
        .lock()
        .unwrap_or_else(|poisoned| poisoned.into_inner());
    failpoint::disarm_all();
    guard
}

fn bits(v: &[f64]) -> Vec<u64> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Register one deterministic model; `(n, d, data_seed, solver_seed)`
/// fully determine it, so a [`ModelSession`] built from the same tuple
/// is an exact (bitwise) twin.
fn registered(n: usize, d: usize, data_seed: u64, solver_seed: u64) -> (Registry, Arc<ModelEntry>) {
    let registry = Registry::new(DEFAULT_BYTE_BUDGET);
    let ds = synthetic::exponential_decay(n, d, data_seed);
    let entry = registry
        .register("stress".into(), ds.a, ds.b, SketchKind::Gaussian, solver_seed)
        .unwrap();
    (registry, entry)
}

fn twin(n: usize, d: usize, data_seed: u64, solver_seed: u64) -> ModelSession {
    let ds = synthetic::exponential_decay(n, d, data_seed);
    ModelSession::new(Arc::new(ds.a), ds.b, SketchKind::Gaussian, solver_seed).unwrap()
}

/// Assert two snapshots describe the same model state bitwise: shape,
/// cached-solution keys in order, and every cached vector to the bit.
fn assert_snapshots_agree(got: &SessionSnapshot, want: &SessionSnapshot, who: &str) {
    assert_eq!(got.n(), want.n(), "{who}: row count diverged at gen {}", got.generation());
    assert_eq!(got.d(), want.d(), "{who}: width diverged");
    assert_eq!(got.m(), want.m(), "{who}: sketch size diverged at gen {}", got.generation());
    assert_eq!(
        got.solution_keys(),
        want.solution_keys(),
        "{who}: cache keys diverged at gen {} (torn read?)",
        got.generation()
    );
    for (nu_bits, eps_bits) in want.solution_keys() {
        let (nu, eps) = (f64::from_bits(nu_bits), f64::from_bits(eps_bits));
        let w = want.cached(nu, eps).expect("key listed but not cached");
        let g = got.cached(nu, eps).expect("key listed but not cached");
        assert_eq!(
            bits(&g.x),
            bits(&w.x),
            "{who}: cached x for nu={nu} diverged at gen {}",
            got.generation()
        );
    }
}

/// The acceptance-criterion smoke test: the read path must not need the
/// session mutex. The main thread *holds* the session lock for the whole
/// duration while a reader answers 500 cached queries from the snapshot
/// handle; if `snapshot()`/`cached()` touched the lock this would
/// deadlock (and the harness would time the test out) instead of passing.
#[test]
fn cached_reads_proceed_while_the_session_lock_is_held() {
    let _guard = suite_lock();
    let (_registry, entry) = registered(64, 8, 40, 7);
    let expected = {
        let mut session = entry.session.lock().unwrap();
        let sol = session.solve(0.5, EPS).unwrap();
        entry.publish(&mut session).unwrap();
        bits(&sol.x)
    };

    let locked = entry.session.lock().unwrap();
    let reader = {
        let entry = Arc::clone(&entry);
        let expected = expected.clone();
        thread::spawn(move || {
            for _ in 0..500 {
                let snap = entry.snapshot();
                let sol = snap.cached(0.5, EPS).expect("published solution missing");
                assert_eq!(bits(&sol.x), expected, "lock-free read diverged");
            }
        })
    };
    reader.join().expect("reader panicked while the writer held the lock");
    drop(locked);
}

/// Solve-only stress: one writer publishes generation g after the g-1'th
/// solve, so a snapshot at generation g must hold *exactly* the first
/// g-1 solutions, in order, bitwise equal to a single-threaded twin.
/// Four readers hammer the entry concurrently; any torn read would show
/// up as a key-count/generation mismatch or foreign bits.
#[test]
fn concurrent_readers_see_only_complete_generations() {
    let _guard = suite_lock();
    const READERS: usize = 4;
    let nus: Vec<f64> = (0..12).map(|i| 0.1 + 0.05 * i as f64).collect();

    let (_registry, entry) = registered(96, 8, 41, 7);
    let mut twin = twin(96, 8, 41, 7);
    let twin_bits: Vec<Vec<u64>> =
        nus.iter().map(|&nu| bits(&twin.solve(nu, EPS).unwrap().x)).collect();

    let done = AtomicBool::new(false);
    let samples = AtomicU64::new(0);
    thread::scope(|scope| {
        for _ in 0..READERS {
            scope.spawn(|| {
                let mut last_gen = 0u64;
                while !done.load(Ordering::Acquire) {
                    let snap = entry.snapshot();
                    let gen = snap.generation();
                    assert!(gen >= last_gen, "generation went backwards: {last_gen} -> {gen}");
                    last_gen = gen;
                    let solved = (gen - 1) as usize;
                    let keys = snap.solution_keys();
                    assert_eq!(keys.len(), solved, "gen {gen} must hold exactly {solved} solves");
                    for (i, &(nu_bits, eps_bits)) in keys.iter().enumerate() {
                        assert_eq!(nu_bits, nus[i].to_bits(), "gen {gen}: key {i} out of order");
                        assert_eq!(eps_bits, EPS.to_bits());
                        let sol = snap.cached(nus[i], EPS).expect("listed key must hit");
                        assert_eq!(bits(&sol.x), twin_bits[i], "gen {gen}: foreign bits at {i}");
                    }
                    for &nu in &nus[solved..] {
                        assert!(snap.cached(nu, EPS).is_none(), "gen {gen} leaked a future solve");
                    }
                    samples.fetch_add(1, Ordering::Relaxed);
                }
            });
        }
        // The writer: solve, publish under the lock, breathe so readers
        // sample several distinct generations.
        for &nu in &nus {
            let mut session = entry.session.lock().unwrap();
            session.solve(nu, EPS).unwrap();
            entry.publish(&mut session).unwrap();
            drop(session);
            thread::sleep(Duration::from_millis(2));
        }
        done.store(true, Ordering::Release);
    });
    assert!(samples.load(Ordering::Relaxed) > 0, "readers never sampled a snapshot");

    let final_snap = entry.snapshot();
    assert_eq!(final_snap.generation(), nus.len() as u64 + 1);
    assert_eq!(final_snap.solution_keys().len(), nus.len());
}

/// Mixed-mutation stress: the writer interleaves solves and eager
/// appends (which retire the whole solution cache) while readers hammer
/// the entry. A per-generation ledger of twin snapshots — produced by an
/// identical single-threaded script — is the oracle: every snapshot a
/// reader loads must agree with its ledger entry bitwise, a pinned old
/// handle must keep answering its own generation's bits forever, and a
/// post-append snapshot must never serve a vector cached before the
/// append (retired-generation isolation).
#[test]
fn interleaved_appends_and_solves_never_tear_reader_snapshots() {
    let _guard = suite_lock();
    const READERS: usize = 4;
    const N0: usize = 60;
    const D: usize = 8;
    const DN: usize = 5;
    const STEPS_DATA_SEED: u64 = 42;

    enum Step {
        Solve(f64),
        Append(usize), // index into the precomputed row deltas
    }
    let script = [
        Step::Solve(0.3),
        Step::Solve(0.55),
        Step::Append(0),
        Step::Solve(0.4),
        Step::Append(1),
        Step::Solve(0.7),
        Step::Solve(0.25),
        Step::Append(2),
        Step::Solve(0.5),
    ];

    // Full dataset split into a base model plus three append deltas.
    let full = synthetic::exponential_decay(N0 + 3 * DN, D, STEPS_DATA_SEED);
    let dense = full.a.dense().into_owned();
    let base = Matrix::from_fn(N0, D, |i, j| dense.get(i, j));
    let deltas: Vec<(Matrix, Vec<f64>)> = (0..3)
        .map(|k| {
            let r0 = N0 + k * DN;
            let m = Matrix::from_fn(DN, D, |i, j| dense.get(r0 + i, j));
            (m, full.b[r0..r0 + DN].to_vec())
        })
        .collect();

    let registry = Registry::new(DEFAULT_BYTE_BUDGET);
    let entry = registry
        .register(
            "mixed".into(),
            Operand::from(base.clone()),
            full.b[..N0].to_vec(),
            SketchKind::Gaussian,
            7,
        )
        .unwrap();

    // Ledger: the twin runs the identical script single-threaded and
    // snapshots after every step; ledger[g-1] is the canonical state at
    // generation g (registration itself published generation 1).
    let mut twin = ModelSession::new(
        Arc::new(Operand::from(base)),
        full.b[..N0].to_vec(),
        SketchKind::Gaussian,
        7,
    )
    .unwrap();
    let mut ledger: Vec<Arc<SessionSnapshot>> = vec![twin.snapshot()];
    for step in &script {
        match step {
            Step::Solve(nu) => {
                twin.solve(*nu, EPS).unwrap();
            }
            Step::Append(k) => {
                let (m, b) = &deltas[*k];
                twin.append(Operand::from(m.clone()), b.clone(), AppendRefresh::Eager).unwrap();
            }
        }
        ledger.push(twin.snapshot());
    }
    for (i, snap) in ledger.iter().enumerate() {
        assert_eq!(snap.generation(), i as u64 + 1, "ledger indexing is off");
    }
    // The script's own sanity: appends really do retire the cache.
    assert!(ledger[3].solution_keys().is_empty(), "append must clear cached solutions");
    assert_eq!(ledger[3].n(), N0 + DN);

    let done = AtomicBool::new(false);
    thread::scope(|scope| {
        for _ in 0..READERS {
            scope.spawn(|| {
                let mut last_gen = 0u64;
                let mut pinned: Option<Arc<SessionSnapshot>> = None;
                while !done.load(Ordering::Acquire) {
                    let snap = entry.snapshot();
                    let gen = snap.generation();
                    assert!(gen >= last_gen, "generation went backwards: {last_gen} -> {gen}");
                    last_gen = gen;
                    assert_snapshots_agree(&snap, &ledger[gen as usize - 1], "reader");
                    pinned.get_or_insert(snap);
                }
                // The first snapshot this reader ever saw must *still*
                // answer exactly what its generation implies, after every
                // append and cache retirement that followed.
                if let Some(old) = pinned {
                    let gen = old.generation();
                    assert_snapshots_agree(&old, &ledger[gen as usize - 1], "pinned reader");
                }
            });
        }
        for step in &script {
            let mut session = entry.session.lock().unwrap();
            match step {
                Step::Solve(nu) => {
                    session.solve(*nu, EPS).unwrap();
                }
                Step::Append(k) => {
                    let (m, b) = &deltas[*k];
                    session
                        .append(Operand::from(m.clone()), b.clone(), AppendRefresh::Eager)
                        .unwrap();
                }
            }
            entry.publish(&mut session).unwrap();
            drop(session);
            thread::sleep(Duration::from_millis(2));
        }
        done.store(true, Ordering::Release);
    });

    // Retired-generation isolation, spelled out: the live snapshot (after
    // the last append + solve) serves only nu = 0.5; every pre-append
    // vector is gone from it, yet a handle pinned to generation 2 still
    // serves the original nu = 0.3 bits.
    let live = entry.snapshot();
    assert_eq!(live.generation(), script.len() as u64 + 1);
    assert_eq!(live.solution_keys(), vec![(0.5f64.to_bits(), EPS.to_bits())]);
    assert!(live.cached(0.3, EPS).is_none(), "retired vector served from live snapshot");
    let old = &ledger[1]; // generation 2: one solve, no appends yet
    assert_eq!(old.n(), N0);
    assert!(old.cached(0.3, EPS).is_some(), "pinned generation lost its own answer");
}

/// Crash-safe publication: a writer that commits a solve but dies at the
/// publish step — injected error and injected panic, both fired at the
/// `session.publish` failpoint *before* the swap — must leave the old
/// snapshot live, bitwise intact, and must never expose the committed-
/// but-unpublished state. A later successful publish then surfaces it
/// (one generation number is burned per failed attempt; monotonicity
/// holds with gaps).
#[test]
fn a_crashed_publish_never_exposes_a_partial_snapshot() {
    let _guard = suite_lock();
    const NU_A: f64 = 0.5;
    const NU_B: f64 = 0.35;

    let (_registry, entry) = registered(64, 8, 43, 7);
    let base_bits = {
        let mut session = entry.session.lock().unwrap();
        let sol = session.solve(NU_A, EPS).unwrap();
        entry.publish(&mut session).unwrap();
        bits(&sol.x)
    };
    let before = entry.snapshot();
    assert_eq!(before.generation(), 2);

    for action in [Action::Error, Action::Panic] {
        failpoint::arm("session.publish", action.clone(), 1);
        let outcome = catch_unwind(AssertUnwindSafe(|| {
            // Poison recovery: the Panic arm of the previous iteration
            // left the mutex poisoned; the state under it is untouched
            // (the failpoint fires before any snapshot swap).
            let mut session = entry.session.lock().unwrap_or_else(|p| p.into_inner());
            session.solve(NU_B, EPS).unwrap(); // the commit succeeds...
            entry.publish(&mut session) // ...the writer dies here
        }));
        match &action {
            Action::Error => {
                let err = outcome.expect("Error action must not panic").unwrap_err();
                assert!(err.contains("injected"), "unexpected publish error: {err}");
            }
            Action::Panic => assert!(outcome.is_err(), "Panic action must unwind"),
            Action::Sleep(_) => unreachable!(),
        }
        // Readers still see the pre-crash world, fully intact.
        let now = entry.snapshot();
        assert_eq!(now.generation(), before.generation(), "crashed publish leaked a swap");
        let sol = now.cached(NU_A, EPS).expect("old snapshot lost its solution");
        assert_eq!(bits(&sol.x), base_bits, "old snapshot corrupted by crashed publish");
        assert!(now.cached(NU_B, EPS).is_none(), "unpublished commit is visible");
    }
    failpoint::disarm_all();

    // The next successful publish surfaces the committed state; the two
    // burned generation numbers (3 and 4) stay skipped forever.
    let published = {
        let mut session = entry.session.lock().unwrap_or_else(|p| p.into_inner());
        let x = session.solve(NU_B, EPS).unwrap().x; // cache hit, no new state
        entry.publish(&mut session).unwrap();
        bits(&x)
    };
    let after = entry.snapshot();
    assert_eq!(after.generation(), 5, "each failed publish burns one generation");
    let sol = after.cached(NU_B, EPS).expect("committed solve still unpublished");
    assert_eq!(bits(&sol.x), published);
    let sol_a = after.cached(NU_A, EPS).expect("older solution evicted unexpectedly");
    assert_eq!(bits(&sol_a.x), base_bits);
}

/// The frozen-lane acceptance criterion: N readers each complete a full
/// *uncached, distinct-nu* solve from the snapshot handle alone while the
/// test holds the session mutex for the whole duration. If
/// `solve_frozen` touched the lock this would deadlock; and every answer
/// must be bitwise what the writer lane would have produced from the
/// same generation (oracle: a fresh twin session per nu, replaying
/// warm-solve → query single-threaded).
#[test]
fn frozen_solves_of_distinct_uncached_nus_proceed_while_the_lock_is_held() {
    let _guard = suite_lock();
    const WARM_NU: f64 = 0.5;
    // Distinct uncached operating points, all with a *smaller* effective
    // dimension than the warm solve's, so the frozen m is sufficient.
    let nus = [0.7, 0.85, 1.0, 1.3, 1.7, 2.2];

    let (_registry, entry) = registered(64, 8, 44, 7);
    {
        let mut session = entry.session.lock().unwrap();
        session.solve(WARM_NU, EPS).unwrap();
        entry.publish(&mut session).unwrap();
    }
    // Oracle: one fresh twin per nu — each replays exactly what the
    // writer lane would do next from the published generation.
    let expected: Vec<Vec<u64>> = nus
        .iter()
        .map(|&nu| {
            let mut t = twin(64, 8, 44, 7);
            t.solve(WARM_NU, EPS).unwrap();
            bits(&t.solve(nu, EPS).unwrap().x)
        })
        .collect();

    let locked = entry.session.lock().unwrap();
    thread::scope(|scope| {
        for (i, &nu) in nus.iter().enumerate() {
            let entry = Arc::clone(&entry);
            let expected = expected[i].clone();
            scope.spawn(move || {
                let snap = entry.snapshot();
                assert!(snap.cached(nu, EPS).is_none(), "nu {nu} must be uncached");
                for _ in 0..20 {
                    let out =
                        snap.solve_frozen(nu, EPS, None).expect("snapshot has state").unwrap();
                    match out {
                        FrozenOutcome::Solved(sol) => {
                            assert!(sol.report.converged);
                            assert_eq!(
                                bits(&sol.x),
                                expected,
                                "frozen solve at nu {nu} diverged from the writer twin"
                            );
                        }
                        FrozenOutcome::NeedsGrowth { reason, .. } => {
                            panic!("nu {nu} must fit the frozen m: {reason}")
                        }
                    }
                }
            });
        }
    });
    drop(locked);
    // The frozen lane populated nothing: every nu is still uncached and
    // the live session still warm-starts from the WARM_NU solution.
    let snap = entry.snapshot();
    for &nu in &nus {
        assert!(snap.cached(nu, EPS).is_none(), "frozen solve must not populate the cache");
    }
}

/// The fallback ladder end-to-end at the registry level: a snapshot
/// whose frozen m is too small for a hard nu defers with `NeedsGrowth`
/// (counted as a fallback), the writer lane grows under the lock and
/// republishes, and the *next* snapshot serves the same nu frozen —
/// bitwise equal to what the writer would answer next.
#[test]
fn needs_growth_falls_back_once_then_the_next_snapshot_serves_frozen() {
    let _guard = suite_lock();
    const EASY_NU: f64 = 50.0; // d_eff ~ 1: tiny frozen m
    const HARD_NU: f64 = 0.05; // d_eff >> frozen m

    let (registry, entry) = registered(512, 64, 45, 7);
    {
        let mut session = entry.session.lock().unwrap();
        session.solve(EASY_NU, EPS).unwrap();
        entry.publish(&mut session).unwrap();
    }

    // The published snapshot's frozen lane cannot serve the hard nu.
    let snap = entry.snapshot();
    let frozen_m = snap.m();
    match snap.solve_frozen(HARD_NU, EPS, None).unwrap().unwrap() {
        FrozenOutcome::NeedsGrowth { m, .. } => {
            assert_eq!(m, frozen_m);
            registry.note_frozen_fallback(&entry);
        }
        FrozenOutcome::Solved(_) => panic!("tiny frozen m must defer to the writer lane"),
    }

    // Writer lane: grow under the lock, republish.
    {
        let mut session = entry.session.lock().unwrap();
        let sol = session.solve(HARD_NU, EPS).unwrap();
        assert!(sol.report.doublings >= 1, "premise: the writer grows here");
        registry.note_query(&entry, &session);
        entry.publish(&mut session).unwrap();
    }

    // The next generation serves the very same nu frozen (different eps
    // so it is a genuine uncached solve, not a cache hit), bitwise equal
    // to the writer twin's next answer.
    let snap2 = entry.snapshot();
    assert!(snap2.m() > frozen_m, "republished snapshot must carry the grown panel");
    let twin_bits = {
        let mut t = twin(512, 64, 45, 7);
        t.solve(EASY_NU, EPS).unwrap();
        t.solve(HARD_NU, EPS).unwrap();
        bits(&t.solve(HARD_NU, EPS / 2.0).unwrap().x)
    };
    match snap2.solve_frozen(HARD_NU, EPS / 2.0, None).unwrap().unwrap() {
        FrozenOutcome::Solved(sol) => {
            registry.note_frozen_solve(&entry);
            assert!(sol.report.converged);
            assert_eq!(bits(&sol.x), twin_bits, "post-growth frozen lane diverged");
        }
        FrozenOutcome::NeedsGrowth { reason, .. } => {
            panic!("grown panel must serve nu {HARD_NU} frozen: {reason}")
        }
    }

    // Counters: one fallback, one frozen solve, and the frozen solve
    // counted as a served query.
    assert_eq!(entry.frozen_fallbacks.load(Ordering::Relaxed), 1);
    assert_eq!(entry.frozen_solves.load(Ordering::Relaxed), 1);
    assert_eq!(registry.frozen_fallbacks.load(Ordering::Relaxed), 1);
    assert_eq!(registry.frozen_solves.load(Ordering::Relaxed), 1);
    assert_eq!(registry.queries.load(Ordering::Relaxed), 2);
}

/// Snapshot isolation under writer-lane growth: a reader pinned to the
/// pre-growth snapshot keeps solving its nu frozen — and keeps getting
/// its own generation's bits — even while the writer grows the panel and
/// republishes. The copy-on-write seam (shared `Arc<GramPanel>`,
/// deep-copy on shared growth) is what makes this safe; this test would
/// catch any in-place mutation of a shared panel.
#[test]
fn a_pinned_snapshot_keeps_its_frozen_answers_across_writer_growth() {
    let _guard = suite_lock();
    const NU: f64 = 0.9;

    let (_registry, entry) = registered(128, 16, 46, 7);
    {
        let mut session = entry.session.lock().unwrap();
        session.solve(0.5, EPS).unwrap();
        entry.publish(&mut session).unwrap();
    }
    let pinned = entry.snapshot();
    let before = match pinned.solve_frozen(NU, EPS, None).unwrap().unwrap() {
        FrozenOutcome::Solved(sol) => bits(&sol.x),
        FrozenOutcome::NeedsGrowth { reason, .. } => panic!("nu {NU} must fit: {reason}"),
    };

    // Writer: force growth (small nu) and republish; the live panel is
    // now a different, larger allocation.
    {
        let mut session = entry.session.lock().unwrap();
        let sol = session.solve(0.01, EPS).unwrap();
        assert!(sol.report.doublings >= 1, "premise: growth happened");
        entry.publish(&mut session).unwrap();
    }
    assert!(entry.snapshot().m() > pinned.m());

    // The pinned handle still answers with its own generation's bits.
    match pinned.solve_frozen(NU, EPS, None).unwrap().unwrap() {
        FrozenOutcome::Solved(sol) => {
            assert_eq!(bits(&sol.x), before, "pinned snapshot's frozen answer changed");
        }
        FrozenOutcome::NeedsGrowth { reason, .. } => {
            panic!("pinned snapshot lost its panel: {reason}")
        }
    }
}
