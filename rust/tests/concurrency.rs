//! Multi-reader serving core: the lock-free snapshot battery.
//!
//! The serving stack publishes each model's state as an immutable
//! [`SessionSnapshot`] behind an RCU cell ([`ModelEntry::snapshot`] /
//! [`ModelEntry::publish`]); this suite pins the three claims that make
//! that safe to serve from:
//!
//! * **no locks on the read path** — a reader answers repeat-`nu` /
//!   cached queries from the snapshot handle alone, even while a writer
//!   holds the session mutex indefinitely;
//! * **no torn reads** — every snapshot any reader ever loads is
//!   bitwise-identical to one of the legal generations a serialized
//!   writer published (never a mix of two), and generations are
//!   monotone per reader;
//! * **crash-safe publication** — a writer that dies (injected error or
//!   panic) between commit and publish leaves the *old* snapshot live
//!   and fully correct; no partial snapshot is ever observable.
//!
//! The `session.publish` failpoint is process-global state, so every
//! test here serializes on one suite mutex and starts disarmed, exactly
//! like `tests/chaos.rs` (armed sites must never leak across tests
//! sharing the process).

use effdim::coordinator::registry::{ModelEntry, Registry, DEFAULT_BYTE_BUDGET};
use effdim::data::synthetic;
use effdim::linalg::Matrix;
use effdim::sketch::SketchKind;
use effdim::solvers::session::{AppendRefresh, ModelSession, SessionSnapshot};
use effdim::util::failpoint::{self, Action};
use effdim::Operand;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, OnceLock};
use std::thread;
use std::time::Duration;

const EPS: f64 = 1e-8;

/// Serialize the suite (failpoints are process-global) and start each
/// test from a disarmed registry.
fn suite_lock() -> MutexGuard<'static, ()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    let guard = LOCK
        .get_or_init(|| Mutex::new(()))
        .lock()
        .unwrap_or_else(|poisoned| poisoned.into_inner());
    failpoint::disarm_all();
    guard
}

fn bits(v: &[f64]) -> Vec<u64> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Register one deterministic model; `(n, d, data_seed, solver_seed)`
/// fully determine it, so a [`ModelSession`] built from the same tuple
/// is an exact (bitwise) twin.
fn registered(n: usize, d: usize, data_seed: u64, solver_seed: u64) -> (Registry, Arc<ModelEntry>) {
    let registry = Registry::new(DEFAULT_BYTE_BUDGET);
    let ds = synthetic::exponential_decay(n, d, data_seed);
    let entry = registry
        .register("stress".into(), ds.a, ds.b, SketchKind::Gaussian, solver_seed)
        .unwrap();
    (registry, entry)
}

fn twin(n: usize, d: usize, data_seed: u64, solver_seed: u64) -> ModelSession {
    let ds = synthetic::exponential_decay(n, d, data_seed);
    ModelSession::new(Arc::new(ds.a), ds.b, SketchKind::Gaussian, solver_seed).unwrap()
}

/// Assert two snapshots describe the same model state bitwise: shape,
/// cached-solution keys in order, and every cached vector to the bit.
fn assert_snapshots_agree(got: &SessionSnapshot, want: &SessionSnapshot, who: &str) {
    assert_eq!(got.n(), want.n(), "{who}: row count diverged at gen {}", got.generation());
    assert_eq!(got.d(), want.d(), "{who}: width diverged");
    assert_eq!(got.m(), want.m(), "{who}: sketch size diverged at gen {}", got.generation());
    assert_eq!(
        got.solution_keys(),
        want.solution_keys(),
        "{who}: cache keys diverged at gen {} (torn read?)",
        got.generation()
    );
    for (nu_bits, eps_bits) in want.solution_keys() {
        let (nu, eps) = (f64::from_bits(nu_bits), f64::from_bits(eps_bits));
        let w = want.cached(nu, eps).expect("key listed but not cached");
        let g = got.cached(nu, eps).expect("key listed but not cached");
        assert_eq!(
            bits(&g.x),
            bits(&w.x),
            "{who}: cached x for nu={nu} diverged at gen {}",
            got.generation()
        );
    }
}

/// The acceptance-criterion smoke test: the read path must not need the
/// session mutex. The main thread *holds* the session lock for the whole
/// duration while a reader answers 500 cached queries from the snapshot
/// handle; if `snapshot()`/`cached()` touched the lock this would
/// deadlock (and the harness would time the test out) instead of passing.
#[test]
fn cached_reads_proceed_while_the_session_lock_is_held() {
    let _guard = suite_lock();
    let (_registry, entry) = registered(64, 8, 40, 7);
    let expected = {
        let mut session = entry.session.lock().unwrap();
        let sol = session.solve(0.5, EPS).unwrap();
        entry.publish(&mut session).unwrap();
        bits(&sol.x)
    };

    let locked = entry.session.lock().unwrap();
    let reader = {
        let entry = Arc::clone(&entry);
        let expected = expected.clone();
        thread::spawn(move || {
            for _ in 0..500 {
                let snap = entry.snapshot();
                let sol = snap.cached(0.5, EPS).expect("published solution missing");
                assert_eq!(bits(&sol.x), expected, "lock-free read diverged");
            }
        })
    };
    reader.join().expect("reader panicked while the writer held the lock");
    drop(locked);
}

/// Solve-only stress: one writer publishes generation g after the g-1'th
/// solve, so a snapshot at generation g must hold *exactly* the first
/// g-1 solutions, in order, bitwise equal to a single-threaded twin.
/// Four readers hammer the entry concurrently; any torn read would show
/// up as a key-count/generation mismatch or foreign bits.
#[test]
fn concurrent_readers_see_only_complete_generations() {
    let _guard = suite_lock();
    const READERS: usize = 4;
    let nus: Vec<f64> = (0..12).map(|i| 0.1 + 0.05 * i as f64).collect();

    let (_registry, entry) = registered(96, 8, 41, 7);
    let mut twin = twin(96, 8, 41, 7);
    let twin_bits: Vec<Vec<u64>> =
        nus.iter().map(|&nu| bits(&twin.solve(nu, EPS).unwrap().x)).collect();

    let done = AtomicBool::new(false);
    let samples = AtomicU64::new(0);
    thread::scope(|scope| {
        for _ in 0..READERS {
            scope.spawn(|| {
                let mut last_gen = 0u64;
                while !done.load(Ordering::Acquire) {
                    let snap = entry.snapshot();
                    let gen = snap.generation();
                    assert!(gen >= last_gen, "generation went backwards: {last_gen} -> {gen}");
                    last_gen = gen;
                    let solved = (gen - 1) as usize;
                    let keys = snap.solution_keys();
                    assert_eq!(keys.len(), solved, "gen {gen} must hold exactly {solved} solves");
                    for (i, &(nu_bits, eps_bits)) in keys.iter().enumerate() {
                        assert_eq!(nu_bits, nus[i].to_bits(), "gen {gen}: key {i} out of order");
                        assert_eq!(eps_bits, EPS.to_bits());
                        let sol = snap.cached(nus[i], EPS).expect("listed key must hit");
                        assert_eq!(bits(&sol.x), twin_bits[i], "gen {gen}: foreign bits at {i}");
                    }
                    for &nu in &nus[solved..] {
                        assert!(snap.cached(nu, EPS).is_none(), "gen {gen} leaked a future solve");
                    }
                    samples.fetch_add(1, Ordering::Relaxed);
                }
            });
        }
        // The writer: solve, publish under the lock, breathe so readers
        // sample several distinct generations.
        for &nu in &nus {
            let mut session = entry.session.lock().unwrap();
            session.solve(nu, EPS).unwrap();
            entry.publish(&mut session).unwrap();
            drop(session);
            thread::sleep(Duration::from_millis(2));
        }
        done.store(true, Ordering::Release);
    });
    assert!(samples.load(Ordering::Relaxed) > 0, "readers never sampled a snapshot");

    let final_snap = entry.snapshot();
    assert_eq!(final_snap.generation(), nus.len() as u64 + 1);
    assert_eq!(final_snap.solution_keys().len(), nus.len());
}

/// Mixed-mutation stress: the writer interleaves solves and eager
/// appends (which retire the whole solution cache) while readers hammer
/// the entry. A per-generation ledger of twin snapshots — produced by an
/// identical single-threaded script — is the oracle: every snapshot a
/// reader loads must agree with its ledger entry bitwise, a pinned old
/// handle must keep answering its own generation's bits forever, and a
/// post-append snapshot must never serve a vector cached before the
/// append (retired-generation isolation).
#[test]
fn interleaved_appends_and_solves_never_tear_reader_snapshots() {
    let _guard = suite_lock();
    const READERS: usize = 4;
    const N0: usize = 60;
    const D: usize = 8;
    const DN: usize = 5;
    const STEPS_DATA_SEED: u64 = 42;

    enum Step {
        Solve(f64),
        Append(usize), // index into the precomputed row deltas
    }
    let script = [
        Step::Solve(0.3),
        Step::Solve(0.55),
        Step::Append(0),
        Step::Solve(0.4),
        Step::Append(1),
        Step::Solve(0.7),
        Step::Solve(0.25),
        Step::Append(2),
        Step::Solve(0.5),
    ];

    // Full dataset split into a base model plus three append deltas.
    let full = synthetic::exponential_decay(N0 + 3 * DN, D, STEPS_DATA_SEED);
    let dense = full.a.dense().into_owned();
    let base = Matrix::from_fn(N0, D, |i, j| dense.get(i, j));
    let deltas: Vec<(Matrix, Vec<f64>)> = (0..3)
        .map(|k| {
            let r0 = N0 + k * DN;
            let m = Matrix::from_fn(DN, D, |i, j| dense.get(r0 + i, j));
            (m, full.b[r0..r0 + DN].to_vec())
        })
        .collect();

    let registry = Registry::new(DEFAULT_BYTE_BUDGET);
    let entry = registry
        .register(
            "mixed".into(),
            Operand::from(base.clone()),
            full.b[..N0].to_vec(),
            SketchKind::Gaussian,
            7,
        )
        .unwrap();

    // Ledger: the twin runs the identical script single-threaded and
    // snapshots after every step; ledger[g-1] is the canonical state at
    // generation g (registration itself published generation 1).
    let mut twin = ModelSession::new(
        Arc::new(Operand::from(base)),
        full.b[..N0].to_vec(),
        SketchKind::Gaussian,
        7,
    )
    .unwrap();
    let mut ledger: Vec<Arc<SessionSnapshot>> = vec![twin.snapshot()];
    for step in &script {
        match step {
            Step::Solve(nu) => {
                twin.solve(*nu, EPS).unwrap();
            }
            Step::Append(k) => {
                let (m, b) = &deltas[*k];
                twin.append(Operand::from(m.clone()), b.clone(), AppendRefresh::Eager).unwrap();
            }
        }
        ledger.push(twin.snapshot());
    }
    for (i, snap) in ledger.iter().enumerate() {
        assert_eq!(snap.generation(), i as u64 + 1, "ledger indexing is off");
    }
    // The script's own sanity: appends really do retire the cache.
    assert!(ledger[3].solution_keys().is_empty(), "append must clear cached solutions");
    assert_eq!(ledger[3].n(), N0 + DN);

    let done = AtomicBool::new(false);
    thread::scope(|scope| {
        for _ in 0..READERS {
            scope.spawn(|| {
                let mut last_gen = 0u64;
                let mut pinned: Option<Arc<SessionSnapshot>> = None;
                while !done.load(Ordering::Acquire) {
                    let snap = entry.snapshot();
                    let gen = snap.generation();
                    assert!(gen >= last_gen, "generation went backwards: {last_gen} -> {gen}");
                    last_gen = gen;
                    assert_snapshots_agree(&snap, &ledger[gen as usize - 1], "reader");
                    pinned.get_or_insert(snap);
                }
                // The first snapshot this reader ever saw must *still*
                // answer exactly what its generation implies, after every
                // append and cache retirement that followed.
                if let Some(old) = pinned {
                    let gen = old.generation();
                    assert_snapshots_agree(&old, &ledger[gen as usize - 1], "pinned reader");
                }
            });
        }
        for step in &script {
            let mut session = entry.session.lock().unwrap();
            match step {
                Step::Solve(nu) => {
                    session.solve(*nu, EPS).unwrap();
                }
                Step::Append(k) => {
                    let (m, b) = &deltas[*k];
                    session
                        .append(Operand::from(m.clone()), b.clone(), AppendRefresh::Eager)
                        .unwrap();
                }
            }
            entry.publish(&mut session).unwrap();
            drop(session);
            thread::sleep(Duration::from_millis(2));
        }
        done.store(true, Ordering::Release);
    });

    // Retired-generation isolation, spelled out: the live snapshot (after
    // the last append + solve) serves only nu = 0.5; every pre-append
    // vector is gone from it, yet a handle pinned to generation 2 still
    // serves the original nu = 0.3 bits.
    let live = entry.snapshot();
    assert_eq!(live.generation(), script.len() as u64 + 1);
    assert_eq!(live.solution_keys(), vec![(0.5f64.to_bits(), EPS.to_bits())]);
    assert!(live.cached(0.3, EPS).is_none(), "retired vector served from live snapshot");
    let old = &ledger[1]; // generation 2: one solve, no appends yet
    assert_eq!(old.n(), N0);
    assert!(old.cached(0.3, EPS).is_some(), "pinned generation lost its own answer");
}

/// Crash-safe publication: a writer that commits a solve but dies at the
/// publish step — injected error and injected panic, both fired at the
/// `session.publish` failpoint *before* the swap — must leave the old
/// snapshot live, bitwise intact, and must never expose the committed-
/// but-unpublished state. A later successful publish then surfaces it
/// (one generation number is burned per failed attempt; monotonicity
/// holds with gaps).
#[test]
fn a_crashed_publish_never_exposes_a_partial_snapshot() {
    let _guard = suite_lock();
    const NU_A: f64 = 0.5;
    const NU_B: f64 = 0.35;

    let (_registry, entry) = registered(64, 8, 43, 7);
    let base_bits = {
        let mut session = entry.session.lock().unwrap();
        let sol = session.solve(NU_A, EPS).unwrap();
        entry.publish(&mut session).unwrap();
        bits(&sol.x)
    };
    let before = entry.snapshot();
    assert_eq!(before.generation(), 2);

    for action in [Action::Error, Action::Panic] {
        failpoint::arm("session.publish", action.clone(), 1);
        let outcome = catch_unwind(AssertUnwindSafe(|| {
            // Poison recovery: the Panic arm of the previous iteration
            // left the mutex poisoned; the state under it is untouched
            // (the failpoint fires before any snapshot swap).
            let mut session = entry.session.lock().unwrap_or_else(|p| p.into_inner());
            session.solve(NU_B, EPS).unwrap(); // the commit succeeds...
            entry.publish(&mut session) // ...the writer dies here
        }));
        match &action {
            Action::Error => {
                let err = outcome.expect("Error action must not panic").unwrap_err();
                assert!(err.contains("injected"), "unexpected publish error: {err}");
            }
            Action::Panic => assert!(outcome.is_err(), "Panic action must unwind"),
            Action::Sleep(_) => unreachable!(),
        }
        // Readers still see the pre-crash world, fully intact.
        let now = entry.snapshot();
        assert_eq!(now.generation(), before.generation(), "crashed publish leaked a swap");
        let sol = now.cached(NU_A, EPS).expect("old snapshot lost its solution");
        assert_eq!(bits(&sol.x), base_bits, "old snapshot corrupted by crashed publish");
        assert!(now.cached(NU_B, EPS).is_none(), "unpublished commit is visible");
    }
    failpoint::disarm_all();

    // The next successful publish surfaces the committed state; the two
    // burned generation numbers (3 and 4) stay skipped forever.
    let published = {
        let mut session = entry.session.lock().unwrap_or_else(|p| p.into_inner());
        let x = session.solve(NU_B, EPS).unwrap().x; // cache hit, no new state
        entry.publish(&mut session).unwrap();
        bits(&x)
    };
    let after = entry.snapshot();
    assert_eq!(after.generation(), 5, "each failed publish burns one generation");
    let sol = after.cached(NU_B, EPS).expect("committed solve still unpublished");
    assert_eq!(bits(&sol.x), published);
    let sol_a = after.cached(NU_A, EPS).expect("older solution evicted unexpectedly");
    assert_eq!(bits(&sol_a.x), base_bits);
}
