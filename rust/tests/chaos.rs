//! Deterministic fault-injection (chaos) suite.
//!
//! Every failpoint compiled into the numerical core and the serving
//! stack is fired here — as an injected error, a panic, or a stall —
//! and the suite pins the three robustness contracts of the PR:
//!
//! * **recovery**: breakdowns inside the solver climb the ladder
//!   (jitter → re-sketch → exact Hessian) and the rung used is visible
//!   in [`SolveReport::recovery`](effdim::SolveReport), while the solve
//!   still answers correctly;
//! * **isolation**: injected (`Internal`) faults and panics roll the
//!   session back all-or-nothing — the next query answers
//!   bitwise-identically to a twin session that never saw the fault;
//! * **serving**: faults surfacing through the TCP server produce
//!   structured `{"ok":false}` errors, never poison a registered model,
//!   and never take the process down.
//!
//! Failpoint state is process-global, so every test serializes on one
//! mutex and starts from a disarmed registry. Armed tests live ONLY in
//! this binary (the library's unit tests run in parallel threads and
//! must never observe an armed site).

use effdim::coordinator::server::{Client, Server};
use effdim::data::synthetic;
use effdim::linalg::Matrix;
use effdim::sketch::SketchKind;
use effdim::solvers::error::RecoveryRung;
use effdim::solvers::session::{AppendRefresh, ModelSession};
use effdim::solvers::{direct, RidgeProblem};
use effdim::util::failpoint::{self, Action};
use effdim::Operand;
use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex, MutexGuard, OnceLock};
use std::time::{Duration, Instant};

/// Serialize the whole suite on one process-global lock and start each
/// test from a disarmed failpoint registry. A test that panicked while
/// holding the lock poisons it; the next test recovers the guard (the
/// registry is re-cleared, so the poison carries no bad state).
fn chaos_lock() -> MutexGuard<'static, ()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    let guard = LOCK
        .get_or_init(|| Mutex::new(()))
        .lock()
        .unwrap_or_else(|poisoned| poisoned.into_inner());
    failpoint::disarm_all();
    guard
}

/// Deterministic session over the synthetic exponential-decay workload;
/// identical `(n, d, data_seed)` + the fixed solver seed make two
/// sessions exact twins (bitwise-identical answers).
fn session(n: usize, d: usize, data_seed: u64) -> (ModelSession, Vec<f64>) {
    let ds = synthetic::exponential_decay(n, d, data_seed);
    let b = ds.b.clone();
    let sess = ModelSession::new(Arc::new(ds.a), ds.b, SketchKind::Gaussian, 7).unwrap();
    (sess, b)
}

/// Direct (Cholesky) reference solution for the session's registered
/// problem at `nu`.
fn reference(sess: &ModelSession, b: &[f64], nu: f64) -> Vec<f64> {
    let atb = sess.operand().matvec_t(b);
    let p = RidgeProblem::from_parts(Arc::clone(sess.operand()), None, atb, nu);
    direct::solve(&p)
}

fn rel_err(x: &[f64], x_star: &[f64]) -> f64 {
    let diff: Vec<f64> = x.iter().zip(x_star).map(|(a, b)| a - b).collect();
    effdim::linalg::norm2(&diff) / (1.0 + effdim::linalg::norm2(x_star))
}

/// Bitwise equality — `f64::to_bits` per entry, stricter than `==`.
fn assert_bitwise(x: &[f64], y: &[f64], what: &str) {
    assert_eq!(x.len(), y.len(), "{what}: length mismatch");
    for (i, (a, b)) in x.iter().zip(y).enumerate() {
        assert!(
            a.to_bits() == b.to_bits(),
            "{what}: entry {i} differs ({a:e} vs {b:e})"
        );
    }
}

/// A deterministic `dn x d` delta block plus observations, disjoint from
/// the synthetic generators' output.
fn delta_rows(dn: usize, d: usize) -> (Operand, Vec<f64>) {
    let m = Matrix::from_fn(dn, d, |i, j| ((i * d + j) as f64 * 0.017).sin());
    let b = (0..dn).map(|i| (i as f64 * 0.029).cos()).collect();
    (Operand::Dense(m), b)
}

// ---------------------------------------------------------------------
// Recovery ladder: breakdowns heal inside the solve and the rung used
// is recorded in the report.
// ---------------------------------------------------------------------

#[test]
fn initial_factor_breakdown_falls_back_to_exact_hessian() {
    let _g = chaos_lock();
    let (mut sess, b) = session(256, 32, 1);
    failpoint::arm("woodbury.factor", Action::Error, 1);
    let sol = sess.solve(0.5, 1e-9).expect("ladder must absorb the initial-factor breakdown");
    assert!(sol.report.converged);
    assert_eq!(sol.report.recovery, RecoveryRung::Exact);
    assert_eq!(sol.report.recovery.label(), "exact");
    let err = rel_err(&sol.x, &reference(&sess, &b, 0.5));
    assert!(err <= 1e-6, "exact-fallback answer off by {err:.3e}");
    failpoint::disarm_all();
}

#[test]
fn rekey_breakdown_resketches_and_the_rung_is_not_sticky() {
    let _g = chaos_lock();
    let (mut sess, b) = session(256, 32, 2);
    let first = sess.solve(0.5, 1e-9).unwrap();
    assert_eq!(first.report.recovery, RecoveryRung::None);

    // The nu re-key path: a factor breakdown while re-keying the cached
    // Woodbury factorization throws the sketch away and re-applies a
    // fresh draw (rung 2), rather than erroring or falling to exact.
    failpoint::arm("woodbury.factor", Action::Error, 1);
    let rekeyed = sess.solve(1.0, 1e-9).expect("re-key breakdown must re-sketch");
    assert!(rekeyed.report.converged);
    assert_eq!(rekeyed.report.recovery, RecoveryRung::Resketch);
    let err = rel_err(&rekeyed.x, &reference(&sess, &b, 1.0));
    assert!(err <= 1e-6, "re-sketched answer off by {err:.3e}");

    // An injected fault in set_nu itself (not the factorization) takes
    // the same rung: anything but invalid input ladders.
    failpoint::arm("woodbury.set_nu", Action::Error, 1);
    let rekeyed2 = sess.solve(0.25, 1e-9).unwrap();
    assert_eq!(rekeyed2.report.recovery, RecoveryRung::Resketch);

    // The rung describes the solve that used it, not the session: a
    // healthy follow-up reports a clean ladder again.
    let healthy = sess.solve(0.7, 1e-9).unwrap();
    assert_eq!(healthy.report.recovery, RecoveryRung::None);
    failpoint::disarm_all();
}

#[test]
fn growth_round_failures_resketch_at_the_grown_size() {
    let _g = chaos_lock();
    // m starts at 1 on this problem and doubles several times before
    // converging, so the first growth round reliably exists to sabotage.
    for site in ["sketch.grow", "woodbury.grow"] {
        let (mut sess, b) = session(256, 32, 3);
        failpoint::arm(site, Action::Error, 1);
        let sol = sess
            .solve(0.3, 1e-9)
            .unwrap_or_else(|e| panic!("growth fault at {site} must be absorbed: {e}"));
        assert!(sol.report.converged);
        assert_eq!(
            sol.report.recovery,
            RecoveryRung::Resketch,
            "failed growth at {site} must re-sketch at the grown size"
        );
        let err = rel_err(&sol.x, &reference(&sess, &b, 0.3));
        assert!(err <= 1e-6, "post-recovery answer off by {err:.3e} ({site})");
    }
    failpoint::disarm_all();
}

// ---------------------------------------------------------------------
// Isolation: injected faults and panics roll back all-or-nothing; the
// next answer is bitwise what a never-faulted twin produces.
// ---------------------------------------------------------------------

#[test]
fn injected_iterate_faults_roll_back_and_answer_bitwise() {
    let _g = chaos_lock();
    let (mut twin, _) = session(256, 32, 4);
    let want = twin.solve(0.5, 1e-9).unwrap().x;

    for action in [Action::Error, Action::Panic] {
        let (mut sess, _) = session(256, 32, 4);
        failpoint::arm("adaptive.iterate", action.clone(), 1);
        let err = sess.solve(0.5, 1e-9).expect_err("armed iterate must fail the solve");
        match action {
            Action::Error => assert!(
                err.contains(r#"injected fault at failpoint "adaptive.iterate""#),
                "{err}"
            ),
            Action::Panic => assert!(
                err.contains(r#"panic: injected panic at failpoint "adaptive.iterate""#),
                "{err}"
            ),
            Action::Sleep(_) => unreachable!(),
        }
        // Rolled back: the retry answers bitwise like the twin's first
        // (and only) solve — no half-grown sketch state leaked out.
        let retry = sess.solve(0.5, 1e-9).unwrap();
        assert_bitwise(&retry.x, &want, "post-fault retry vs never-faulted twin");
        assert_eq!(retry.report.recovery, RecoveryRung::None);
    }
    failpoint::disarm_all();
}

#[test]
fn failed_appends_roll_back_bitwise_and_the_session_still_ingests() {
    let _g = chaos_lock();
    let (mut twin, _) = session(192, 16, 5);
    twin.solve(0.5, 1e-9).unwrap();

    let (mut sess, _) = session(192, 16, 5);
    sess.solve(0.5, 1e-9).unwrap();
    let (n0, m0, bytes0) = (sess.n(), sess.m(), sess.approx_bytes());

    let (da, db) = delta_rows(8, 16);
    for action in [Action::Error, Action::Panic] {
        failpoint::arm("session.append", action.clone(), 1);
        let err = sess
            .append(da.clone(), db.clone(), AppendRefresh::Eager)
            .expect_err("armed append must fail");
        match action {
            Action::Error => assert!(
                err.contains(r#"injected fault at failpoint "session.append""#),
                "{err}"
            ),
            Action::Panic => assert!(
                err.contains(r#"panic: injected panic at failpoint "session.append""#),
                "{err}"
            ),
            Action::Sleep(_) => unreachable!(),
        }
        // Full rollback: rows, sketch size, and byte accounting are
        // exactly the pre-append values.
        assert_eq!(sess.n(), n0, "failed append leaked rows");
        assert_eq!(sess.m(), m0, "failed append changed the sketch");
        assert_eq!(sess.approx_bytes(), bytes0, "failed append changed the byte footprint");
    }

    // The rolled-back session is not just intact but still bitwise the
    // twin: the same (now unarmed) append + solve on both must agree.
    sess.append(da.clone(), db.clone(), AppendRefresh::Eager).unwrap();
    twin.append(da, db, AppendRefresh::Eager).unwrap();
    let x_sess = sess.solve(0.5, 1e-9).unwrap().x;
    let x_twin = twin.solve(0.5, 1e-9).unwrap().x;
    assert_bitwise(&x_sess, &x_twin, "append-after-rollback vs twin");
    failpoint::disarm_all();
}

#[test]
fn flush_fault_propagates_and_the_pending_rows_survive() {
    let _g = chaos_lock();
    let (mut twin, _) = session(192, 16, 6);
    twin.solve(0.5, 1e-9).unwrap();
    let (mut sess, _) = session(192, 16, 6);
    sess.solve(0.5, 1e-9).unwrap();

    let (da, db) = delta_rows(8, 16);
    sess.append(da.clone(), db.clone(), AppendRefresh::Lazy).unwrap();
    twin.append(da, db, AppendRefresh::Lazy).unwrap();
    let n_grown = sess.n();

    // The deferred flush runs at the head of the next solve; an injected
    // fault there fails that solve but must not lose the appended rows
    // or corrupt the pending buffer.
    failpoint::arm("session.flush", Action::Error, 1);
    let err = sess.solve(0.5, 1e-9).expect_err("armed flush must fail the solve");
    assert!(err.contains(r#"injected fault at failpoint "session.flush""#), "{err}");
    assert_eq!(sess.n(), n_grown, "appended rows must survive a failed flush");

    // Disarmed retry: the flush completes and the answer is bitwise the
    // twin's (same lazy append, never-faulted flush).
    let x_sess = sess.solve(0.5, 1e-9).unwrap().x;
    let x_twin = twin.solve(0.5, 1e-9).unwrap().x;
    assert_bitwise(&x_sess, &x_twin, "flush-after-fault vs twin");
    failpoint::disarm_all();
}

#[test]
fn sketch_append_panic_takes_the_session_resketch_rung() {
    let _g = chaos_lock();
    let (mut sess, b) = session(192, 16, 8);
    sess.solve(0.5, 1e-9).unwrap();
    let (da, db) = delta_rows(8, 16);
    sess.append(da, db, AppendRefresh::Lazy).unwrap();

    // An injected *error* in the engine's row-append is an Internal
    // fault: it propagates (tested via the wire contract above); a
    // *panic* during the staged absorb is indistinguishable from a
    // numerical breakdown, so the flush takes the session-level
    // re-sketch rung instead: the resumable state is dropped and the
    // solve rebuilds the sketch over the grown operand — no data lost,
    // no error surfaced.
    failpoint::arm("sketch.append", Action::Panic, 1);
    let sol = sess.solve(0.5, 1e-9).expect("flush panic must be absorbed by re-sketching");
    assert!(sol.report.converged);
    assert!(sess.m() >= 1, "re-sketch must leave a live sketch behind");
    let err = rel_err(&sol.x, &reference(&sess, &b, 0.5));
    assert!(err <= 1e-6, "re-sketched answer off by {err:.3e}");

    // The error flavor of the same site propagates un-laddered.
    let (da2, db2) = delta_rows(4, 16);
    sess.append(da2, db2, AppendRefresh::Lazy).unwrap();
    failpoint::arm("sketch.append", Action::Error, 1);
    let msg = sess.solve(0.7, 1e-9).expect_err("injected engine fault must propagate");
    assert!(msg.contains(r#"injected fault at failpoint "sketch.append""#), "{msg}");
    let retry = sess.solve(0.7, 1e-9).unwrap();
    assert!(retry.report.converged);
    failpoint::disarm_all();
}

#[test]
fn block_solve_faults_are_isolated() {
    let _g = chaos_lock();
    let bs: Vec<Vec<f64>> = (0..3)
        .map(|j| (0..192).map(|i| ((i * (j + 2)) as f64 * 0.013).sin()).collect())
        .collect();
    let (mut twin, _) = session(192, 16, 9);
    let want: Vec<Vec<f64>> =
        twin.solve_block(0.5, &bs, 1e-9).unwrap().into_iter().map(|s| s.x).collect();

    let (mut sess, _) = session(192, 16, 9);
    failpoint::arm("block.iterate", Action::Error, 1);
    let err = sess.solve_block(0.5, &bs, 1e-9).expect_err("armed block iterate must fail");
    assert!(err.contains(r#"injected fault at failpoint "block.iterate""#), "{err}");

    let got = sess.solve_block(0.5, &bs, 1e-9).unwrap();
    for (j, (sol, want_x)) in got.iter().zip(&want).enumerate() {
        assert_bitwise(&sol.x, want_x, &format!("block column {j} after rollback"));
    }
    failpoint::disarm_all();
}

#[test]
fn injected_stall_trips_the_deadline_and_the_session_recovers() {
    let _g = chaos_lock();
    let (mut sess, _) = session(256, 32, 10);
    // A healthy solve finishes far inside 100ms; the injected 250ms
    // stall pushes the first iterate past the wall and the cooperative
    // deadline check turns it into a structured error.
    failpoint::arm("adaptive.iterate", Action::Sleep(250), 1);
    sess.set_deadline(Some(Instant::now() + Duration::from_millis(100)));
    let err = sess.solve(0.5, 1e-9).expect_err("stalled solve must miss its deadline");
    assert!(err.contains("deadline"), "{err}");

    sess.set_deadline(None);
    let sol = sess.solve(0.5, 1e-9).expect("session must recover after a missed deadline");
    assert!(sol.report.converged);
    failpoint::disarm_all();
}

// ---------------------------------------------------------------------
// External arming: the EFFDIM_FAILPOINTS env contract chaos drivers use.
// ---------------------------------------------------------------------

#[test]
fn env_var_arming_drives_faults_and_rejects_typos() {
    let _g = chaos_lock();
    std::env::set_var("EFFDIM_FAILPOINTS", "adaptive.iterate=error");
    let armed = failpoint::arm_from_env();
    std::env::remove_var("EFFDIM_FAILPOINTS");
    armed.expect("valid spec must arm");

    let (mut sess, _) = session(192, 16, 11);
    let err = sess.solve(0.5, 1e-9).expect_err("env-armed failpoint must fire");
    assert!(err.contains(r#"injected fault at failpoint "adaptive.iterate""#), "{err}");
    assert!(sess.solve(0.5, 1e-9).is_ok(), "env-armed failpoints self-disarm");

    // A typo'd spec is an error, not a vacuous chaos run.
    std::env::set_var("EFFDIM_FAILPOINTS", "woodbury.factor=explode");
    let rejected = failpoint::arm_from_env();
    std::env::remove_var("EFFDIM_FAILPOINTS");
    assert!(rejected.is_err(), "unknown actions must be rejected");
    failpoint::disarm_all();
}

// ---------------------------------------------------------------------
// Serving: faults crossing the TCP boundary are structured errors; the
// registered model survives and keeps answering bitwise.
// ---------------------------------------------------------------------

#[test]
fn server_survives_injected_faults_and_models_keep_answering_bitwise() {
    let _g = chaos_lock();
    let server = Server::bind("127.0.0.1:0", 1).unwrap();
    let addr = server.local_addr();
    let stop = server.stop_handle();
    let handle = std::thread::spawn(move || server.run());
    let mut client = Client::connect(addr).unwrap();

    let reg = client
        .call(r#"{"cmd":"register","profile":"exp","n":256,"d":32,"seed":5,"sketch":"gaussian"}"#)
        .unwrap();
    assert_eq!(reg.get("ok").unwrap().as_bool(), Some(true), "{reg:?}");
    let model = reg.get("model").unwrap().as_usize().unwrap();

    let xs = |resp: &effdim::util::json::Json| -> Vec<f64> {
        resp.get("result")
            .unwrap()
            .get("x")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_f64().unwrap())
            .collect()
    };

    let q1 = client
        .call(&format!(r#"{{"cmd":"query","model":{model},"nu":0.3,"eps":1e-8,"x":true}}"#))
        .unwrap();
    assert_eq!(q1.get("ok").unwrap().as_bool(), Some(true), "{q1:?}");
    let x1 = xs(&q1);

    // A re-key breakdown mid-request heals inside the solver; the wire
    // sees a successful answer that *declares* its degraded path.
    failpoint::arm("woodbury.factor", Action::Error, 1);
    let degraded = client
        .call(&format!(r#"{{"cmd":"query","model":{model},"nu":1.0,"eps":1e-8}}"#))
        .unwrap();
    assert_eq!(degraded.get("ok").unwrap().as_bool(), Some(true), "{degraded:?}");
    assert_eq!(
        degraded.get("result").unwrap().get("recovery").unwrap().as_str(),
        Some("resketch"),
        "{degraded:?}"
    );

    // An unrecoverable injected fault is a structured refusal — the
    // connection stays up and the model stays registered.
    failpoint::arm("adaptive.iterate", Action::Error, 1);
    let refused = client
        .call(&format!(r#"{{"cmd":"query","model":{model},"nu":0.07,"eps":1e-8}}"#))
        .unwrap();
    assert_eq!(refused.get("ok").unwrap().as_bool(), Some(false), "{refused:?}");
    assert!(
        refused.get("error").unwrap().as_str().unwrap().contains("injected fault"),
        "{refused:?}"
    );

    // The original answer is still served bitwise (solution cache and
    // session state untouched by either fault).
    let q1_again = client
        .call(&format!(r#"{{"cmd":"query","model":{model},"nu":0.3,"eps":1e-8,"x":true}}"#))
        .unwrap();
    assert_eq!(q1_again.get("ok").unwrap().as_bool(), Some(true), "{q1_again:?}");
    assert_bitwise(&xs(&q1_again), &x1, "wire re-answer after faults");

    let health = client.call(r#"{"cmd":"health"}"#).unwrap();
    assert_eq!(health.get("ok").unwrap().as_bool(), Some(true), "{health:?}");
    assert_eq!(health.get("models").unwrap().as_usize(), Some(1), "{health:?}");

    stop.store(true, Ordering::SeqCst);
    handle.join().unwrap();
    failpoint::disarm_all();
}
